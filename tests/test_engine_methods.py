"""Engine-complete round closes: svd / assignment methods + double buffering.

Contracts under test (see core/engine.py):

* ``factored_truncated_residual`` equals the dense Eckart–Young oracle to
  the documented ~1e-5 relative tolerance across ranks, weights and masked
  (partial-participation) lanes — and its jaxpr contains NO (m, n)-shaped
  intermediate: the truncation lives entirely on (m, C·r) / (C·r, n) /
  (C·r, C·r) arrays. Every eigendecomposition/SVD in the full svd-close
  program acts on C·r-sized matrices (the eager path SVDs the dense m×n
  residual; the engine never does).
* The engine ``fedex_svd`` close matches the eager
  ``fedex_svd_aggregate + apply_residual`` oracle within that tolerance.
* The engine ``keep_local`` / ``reinit`` closes are exact against the eager
  assignment oracles: bitwise vs the *jitted* operator composition on
  uniform full-participation rounds, tight allclose on weighted/ragged
  rounds; reinit redraws bitwise-identical adapters from the same rng.
* ``RoundBuffers`` double-buffering: two rounds' writes interleave into
  separate ring sets keyed by round_id, ``take()`` pops FIFO, and depth
  exhaustion raises instead of overwriting an un-closed round.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, LoRAConfig, validate_fed_lora
from repro.core import aggregation as agg
from repro.core.engine import (RoundBuffers, RoundCloseEngine,
                               factored_truncated_residual, make_close_fn,
                               build_factor_specs)
from repro.kernels import perclient_fold, product_fold
from repro.kernels import ref
from repro.util.tree import flatten_with_paths


def _mk(rng, sh):
    return jnp.asarray(rng.normal(size=sh), jnp.float32)


def _rand_weights(rng, k):
    w = rng.uniform(0.2, 5.0, size=k)
    return (w / w.sum()).tolist()


def _assert_bitwise(a, b, msg=""):
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=f"{msg} at {k}")


def _assert_close(a, b, tol=1e-5, msg=""):
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_allclose(np.asarray(fa[k], np.float32),
                                   np.asarray(fb[k], np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{msg} at {k}")


def _dense_residual(a, b, w):
    """Oracle: Σw_c a_c b_c − ā b̄ fully materialised."""
    return (jnp.einsum("c,cmr,crn->mn", w, a, b)
            - jnp.einsum("c,cmr->mr", w, a) @ jnp.einsum("c,crn->rn", w, b))


def _dense_truncation(dense, rank):
    u, s, vt = np.linalg.svd(np.asarray(dense), full_matrices=False)
    return (u[:, :rank] * s[:rank]) @ vt[:rank]


def _walk_avals(jaxpr):
    """Every intermediate aval in a jaxpr, recursing into sub-jaxprs."""
    out = []
    for eqn in jaxpr.eqns:
        out += [(eqn.primitive.name, v.aval) for v in eqn.outvars]
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for v in vals:
                if isinstance(v, jax.core.ClosedJaxpr):
                    out += _walk_avals(v.jaxpr)
                elif isinstance(v, jax.core.Jaxpr):
                    out += _walk_avals(v)
    return out


# --------------------------------------------------------------------------
# the factored truncation vs the dense Eckart–Young oracle
# --------------------------------------------------------------------------

class TestFactoredTruncation:
    @pytest.mark.parametrize("rank", [1, 4, 16])
    @pytest.mark.parametrize("weighting", ["uniform", "random"])
    def test_matches_dense_oracle(self, rank, weighting):
        rng = np.random.default_rng(rank * 7 + len(weighting))
        c, m, r, n = 4, 96, 4, 80
        a, b = _mk(rng, (c, m, r)), _mk(rng, (c, r, n))
        w = (np.full(c, 1.0 / c) if weighting == "uniform"
             else np.asarray(_rand_weights(rng, c)))
        w = jnp.asarray(w, jnp.float32)
        ap, bp = factored_truncated_residual(a, b, w, rank)
        assert ap.shape == (m, rank) and bp.shape == (rank, n)
        best = _dense_truncation(_dense_residual(a, b, w), rank)
        scale = max(np.abs(best).max(), 1e-6)
        np.testing.assert_allclose(np.asarray(ap @ bp) / scale, best / scale,
                                   atol=1e-4)

    def test_masked_lanes_match_subset_oracle(self):
        """C_max-padded stacks with zero-weight lanes truncate identically to
        the dense oracle over the delivered subset."""
        rng = np.random.default_rng(0)
        c_max, m, r, n = 6, 64, 4, 48
        a, b = _mk(rng, (c_max, m, r)), _mk(rng, (c_max, r, n))
        delivered = [1, 3, 4]
        w_sub = _rand_weights(rng, len(delivered))
        w = np.zeros(c_max, np.float32)
        for i, wi in zip(delivered, w_sub):
            w[i] = wi
        w = jnp.asarray(w)
        for rank in (2, 8):
            ap, bp = factored_truncated_residual(a, b, w, rank)
            best = _dense_truncation(_dense_residual(a, b, w), rank)
            scale = max(np.abs(best).max(), 1e-6)
            np.testing.assert_allclose(np.asarray(ap @ bp) / scale,
                                       best / scale, atol=1e-4)

    def test_full_rank_reconstructs_exactly(self):
        """r' = k·r reproduces the untruncated residual (the exact close)."""
        rng = np.random.default_rng(1)
        c, m, r, n = 3, 48, 4, 40
        a, b = _mk(rng, (c, m, r)), _mk(rng, (c, r, n))
        w = jnp.full((c,), 1.0 / c, jnp.float32)
        ap, bp = factored_truncated_residual(a, b, w, c * r)
        dense = _dense_residual(a, b, w)
        np.testing.assert_allclose(np.asarray(ap @ bp), np.asarray(dense),
                                   rtol=1e-4, atol=1e-5)

    def test_stacked_layer_axes_batch_through(self):
        rng = np.random.default_rng(2)
        c, L, m, r, n = 3, 4, 32, 4, 24
        a, b = _mk(rng, (c, L, m, r)), _mk(rng, (c, L, r, n))
        w = jnp.asarray(_rand_weights(rng, c), jnp.float32)
        ap, bp = factored_truncated_residual(a, b, w, 4)
        assert ap.shape == (L, m, 4) and bp.shape == (L, 4, n)
        for l in range(L):
            best = _dense_truncation(_dense_residual(a[:, l], b[:, l], w), 4)
            scale = max(np.abs(best).max(), 1e-6)
            np.testing.assert_allclose(np.asarray(ap[l] @ bp[l]) / scale,
                                       best / scale, atol=1e-4)

    def test_jaxpr_contains_no_dense_intermediate(self):
        """THE no-dense contract: every intermediate of the truncation is
        (m, C·r) / (C·r, n) / (C·r, C·r)-sized — the (m, n) deviation matrix
        the eager path SVDs is never formed."""
        c, m, r, n = 4, 96, 4, 80

        def f(a, b, w):
            return factored_truncated_residual(a, b, w, 8)

        jaxpr = jax.make_jaxpr(f)(jnp.zeros((c, m, r)), jnp.zeros((c, r, n)),
                                  jnp.zeros((c,)))
        dense = [(name, aval) for name, aval in _walk_avals(jaxpr.jaxpr)
                 if getattr(aval, "shape", ())[-2:] == (m, n)]
        assert not dense, f"dense (m, n) intermediates found: {dense}"

    def test_svd_close_program_decomposes_small_matrices_only(self):
        """HLO-level assertion on the FULL svd close program: every
        eigendecomposition / SVD acts on matrices of size ≤ C·r — the eager
        close's jnp.linalg.svd over the dense (m, n) residual never appears."""
        rng = np.random.default_rng(3)
        c, m, r, n = 4, 96, 4, 80
        params = {"q": {"kernel": _mk(rng, (m, n))}}
        lora_t = {"q": {"a": _mk(rng, (m, r)), "b": _mk(rng, (r, n))}}
        specs = build_factor_specs(params, lora_t)
        close = make_close_fn(specs, scale=1.0, c_max=c, method="fedex_svd",
                              svd_rank=8, backend="jnp", donate=False)
        w0 = {"q": params["q"]["kernel"]}
        stacks = {"q/a": jnp.zeros((c, m, r)), "q/b": jnp.zeros((c, r, n))}
        jaxpr = jax.make_jaxpr(
            functools.partial(close, uniform=False)
        )(w0, stacks, jnp.zeros((c,)), jnp.zeros((c,)))
        p = c * r
        decomps = [(name, aval) for name, aval in _walk_avals(jaxpr.jaxpr)
                   if ("eig" in name or "svd" in name or "qr" in name)
                   and getattr(aval, "ndim", 0) >= 2]
        assert decomps, "no decomposition found — did the close change?"
        for name, aval in decomps:
            assert max(aval.shape[-2:]) <= p, (
                f"{name} on {aval.shape}: decomposition touched a matrix "
                f"larger than C·r = {p}")


# --------------------------------------------------------------------------
# engine svd close vs the eager dense-SVD oracle
# --------------------------------------------------------------------------

def _make_setting(rng, c, with_moe=False, layers=None, m=48, r=4, n=32):
    lead = () if layers is None else (layers,)
    params = {"blk": {"q_proj": {"kernel": _mk(rng, lead + (m, n)),
                                 "bias": _mk(rng, (n,))}}}
    lora_t = {"blk": {"q_proj": {"a": _mk(rng, lead + (m, r)),
                                 "b": _mk(rng, lead + (r, n))}}}
    if with_moe:
        params["blk"]["experts"] = {"w_up": _mk(rng, (2, m, n))}
        lora_t["blk"]["experts"] = {"w_up": {"a": _mk(rng, (2, m, r)),
                                             "b": _mk(rng, (2, r, n))}}

    def client(seed):
        crng = np.random.default_rng(seed)
        t = {"blk": {"q_proj": {"a": _mk(crng, lead + (m, r)),
                                "b": _mk(crng, lead + (r, n))}}}
        if with_moe:
            t["blk"]["experts"] = {"w_up": {"a": _mk(crng, (2, m, r)),
                                            "b": _mk(crng, (2, r, n))}}
        return t

    return params, lora_t, [client(100 + i) for i in range(c)]


class TestSvdEngineClose:
    @pytest.mark.parametrize("svd_rank", [1, 4, 8])
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_matches_eager_dense_oracle(self, svd_rank, backend):
        rng = np.random.default_rng(svd_rank)
        c, scale = 4, 1.3
        params, lora_t, loras = _make_setting(rng, c)
        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                               method="fedex_svd", svd_rank=svd_rank,
                               backend=backend, interpret=True)
        eng.buffers.begin_round({i: i for i in range(c)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        g_e, p_e, div = eng.close(params, list(range(c)))

        g_l, res_t = agg.fedex_svd_aggregate(loras, svd_rank)
        p_l = agg.apply_residual(params, res_t, scale)
        _assert_close(p_e, p_l, tol=1e-4, msg="params")
        _assert_close(g_e, g_l, tol=1e-5, msg="global")
        assert div > 0

    def test_weighted_partial_matches_subset_oracle(self):
        rng = np.random.default_rng(10)
        c_max, scale, svd_rank = 5, 2.0, 6
        params, lora_t, loras = _make_setting(rng, c_max)
        eng = RoundCloseEngine(params, lora_t, c_max=c_max, scale=scale,
                               method="fedex_svd", svd_rank=svd_rank,
                               backend="jnp")
        eng.buffers.begin_round({i: i for i in range(c_max)})
        delivered = [0, 2, 4]
        for i in delivered:
            eng.buffers.write(i, loras[i])
        weights = [10.0, 30.0, 60.0]
        g_e, p_e, _ = eng.close(params, delivered, weights)

        sub = [loras[i] for i in delivered]
        g_l, res_t = agg.fedex_svd_aggregate(sub, svd_rank, weights)
        p_l = agg.apply_residual(params, res_t, scale)
        _assert_close(p_e, p_l, tol=1e-4, msg="params")
        _assert_close(g_e, g_l, tol=1e-5, msg="global")

    def test_moe_and_stacked_layers(self):
        rng = np.random.default_rng(11)
        c, scale, svd_rank = 3, 1.0, 4
        params, lora_t, loras = _make_setting(rng, c, with_moe=True, layers=3)
        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                               method="fedex_svd", svd_rank=svd_rank,
                               backend="jnp")
        eng.buffers.begin_round({i: i for i in range(c)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        _, p_e, _ = eng.close(params, list(range(c)))
        _, res_t = agg.fedex_svd_aggregate(loras, svd_rank)
        p_l = agg.apply_residual(params, res_t, scale)
        _assert_close(p_e, p_l, tol=1e-4, msg="params")


# --------------------------------------------------------------------------
# engine assignment closes vs the eager Table-5 oracles
# --------------------------------------------------------------------------

class TestKeepLocalEngineClose:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_uniform_bitwise_vs_jitted_oracle(self, backend):
        """Full-participation uniform keep_local close ≡ the jitted
        composition of per_client_residuals + apply_residual, bitwise — on
        EVERY backend (the uniform branch is backend-independent, like
        fedex's)."""
        rng = np.random.default_rng(0)
        c, scale = 4, 1.3
        params, lora_t, loras = _make_setting(rng, c)
        client_params = [
            _make_setting(np.random.default_rng(500 + i), c)[0]
            for i in range(c)
        ]
        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                               method="keep_local", backend=backend,
                               interpret=True)
        eng.buffers.begin_round({i: i for i in range(c)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        new_cp, div = eng.close_keep_local(client_params, list(range(c)))

        @jax.jit
        def oracle(cps, loras):
            residuals = agg.per_client_residuals(loras)
            return [agg.apply_residual(p, r_i, scale)
                    for p, r_i in zip(cps, residuals)]

        expect = oracle(client_params, loras)
        for i in range(c):
            _assert_bitwise(new_cp[i], expect[i], f"client {i}")
        assert div > 0

    def test_weighted_partial_matches_eager_oracle(self):
        rng = np.random.default_rng(1)
        c_max, scale = 5, 0.7
        params, lora_t, loras = _make_setting(rng, c_max)
        client_params = [
            _make_setting(np.random.default_rng(600 + i), c_max)[0]
            for i in range(c_max)
        ]
        eng = RoundCloseEngine(params, lora_t, c_max=c_max, scale=scale,
                               method="keep_local", backend="jnp")
        eng.buffers.begin_round({i: i for i in range(c_max)})
        delivered = [1, 2, 4]
        for i in delivered:
            eng.buffers.write(i, loras[i])
        weights = [20.0, 30.0, 50.0]
        new_cp, _ = eng.close_keep_local(client_params, delivered, weights)

        sub = [loras[i] for i in delivered]
        residuals = agg.per_client_residuals(sub, weights)
        for cid, res_i in zip(delivered, residuals):
            expect = agg.apply_residual(client_params[cid], res_i, scale)
            _assert_close(new_cp[cid], expect, tol=2e-5, msg=f"client {cid}")
        # non-delivered clients aren't touched
        assert set(new_cp) == set(delivered)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_pallas_kernel_path_matches(self, backend):
        rng = np.random.default_rng(2)
        c, scale = 3, 1.1
        params, lora_t, loras = _make_setting(rng, c)
        client_params = [
            _make_setting(np.random.default_rng(700 + i), c)[0]
            for i in range(c)
        ]
        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                               method="keep_local", backend=backend,
                               interpret=True)
        eng.buffers.begin_round({i: i for i in range(c)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        weights = [1.0, 2.0, 3.0]  # force the weighted (non-uniform) branch
        new_cp, _ = eng.close_keep_local(client_params, list(range(c)),
                                         weights)
        residuals = agg.per_client_residuals(loras, weights)
        for i in range(c):
            expect = agg.apply_residual(client_params[i], residuals[i], scale)
            _assert_close(new_cp[i], expect, tol=2e-5, msg=f"client {i}")

    def test_wrong_method_raises(self):
        rng = np.random.default_rng(3)
        params, lora_t, loras = _make_setting(rng, 2)
        eng = RoundCloseEngine(params, lora_t, c_max=2, scale=1.0,
                               method="keep_local", backend="jnp")
        eng.buffers.begin_round({0: 0, 1: 1})
        eng.buffers.write(0, loras[0])
        with pytest.raises(ValueError, match="close_keep_local"):
            eng.close(params, [0])
        eng2 = RoundCloseEngine(params, lora_t, c_max=2, scale=1.0,
                                method="fedex", backend="jnp")
        eng2.buffers.begin_round({0: 0, 1: 1})
        eng2.buffers.write(0, loras[0])
        with pytest.raises(ValueError, match="keep_local"):
            eng2.close_keep_local([params, params], [0])


class TestReinitEngineClose:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_uniform_bitwise_vs_jitted_oracle(self, backend):
        rng = np.random.default_rng(0)
        c, scale = 4, 1.3
        params, lora_t, loras = _make_setting(rng, c)
        eng = RoundCloseEngine(params, lora_t, c_max=c, scale=scale,
                               method="reinit", backend=backend,
                               interpret=True)
        eng.buffers.begin_round({i: i for i in range(c)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        key = jax.random.key(42)
        g_e, p_e, div = eng.close(params, list(range(c)), rng=key)

        @jax.jit
        def oracle(params, loras):
            ideal = agg.product_mean(loras)
            return agg.apply_residual(params, ideal, scale)

        _assert_bitwise(p_e, oracle(params, loras), "params")
        # adapters: both paths draw host-side through the SAME
        # reinit_adapters helper — bitwise by construction (a jitted redraw
        # differs by 1 ulp where XLA fuses the 0.02 scaling)
        new_loras, _ = agg.assign_after_aggregation("reinit", loras, key)
        _assert_bitwise(g_e, new_loras[0], "reinit adapters")
        assert div > 0

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_weighted_partial_matches_eager_oracle(self, backend):
        rng = np.random.default_rng(1)
        c_max, scale = 5, 2.0
        params, lora_t, loras = _make_setting(rng, c_max)
        eng = RoundCloseEngine(params, lora_t, c_max=c_max, scale=scale,
                               method="reinit", backend=backend,
                               interpret=True)
        eng.buffers.begin_round({i: i for i in range(c_max)})
        delivered = [0, 3]
        for i in delivered:
            eng.buffers.write(i, loras[i])
        weights = [30.0, 70.0]
        key = jax.random.key(7)
        g_e, p_e, _ = eng.close(params, delivered, weights, rng=key)

        sub = [loras[i] for i in delivered]
        new_loras, residual = agg.assign_after_aggregation(
            "reinit", sub, jax.random.key(7), weights)
        p_l = agg.apply_residual(params, residual, scale)
        _assert_close(p_e, p_l, tol=2e-5, msg="params")
        _assert_bitwise(g_e, new_loras[0], "reinit adapters")

    def test_missing_rng_raises(self):
        rng = np.random.default_rng(2)
        params, lora_t, loras = _make_setting(rng, 2)
        eng = RoundCloseEngine(params, lora_t, c_max=2, scale=1.0,
                               method="reinit", backend="jnp")
        eng.buffers.begin_round({0: 0, 1: 1})
        eng.buffers.write(0, loras[0])
        with pytest.raises(ValueError, match="rng"):
            eng.close(params, [0])


# --------------------------------------------------------------------------
# kernel variants vs their jnp oracles
# --------------------------------------------------------------------------

class TestFoldKernelVariants:
    def test_product_fold_signed_and_masked(self):
        rng = np.random.default_rng(0)
        c, m, r, n = 4, 130, 4, 257  # tile-indivisible dims pad exactly
        w0 = _mk(rng, (m, n))
        a, b = _mk(rng, (c, m, r)), _mk(rng, (c, r, n))
        s = jnp.asarray([0.5, -1.0, 0.0, 0.3], jnp.float32)
        out = product_fold(w0, a, b, s, 1.7, interpret=True)
        expect = ref.product_fold_ref(w0, a, b, s, 1.7)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-4)

    def test_product_fold_single_lane_is_lowrank_fold(self):
        """One lane with s=[1]: exactly W0 + scale·A'B' — the svd close's
        factored-residual fold."""
        rng = np.random.default_rng(1)
        m, rank, n = 64, 6, 48
        w0, ap, bp = _mk(rng, (m, n)), _mk(rng, (m, rank)), _mk(rng, (rank, n))
        out = product_fold(w0, ap[None], bp[None],
                           jnp.ones((1,), jnp.float32), 2.0, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(w0 + 2.0 * ap @ bp),
                                   rtol=1e-5, atol=1e-5)

    def test_perclient_fold_matches_ref(self):
        rng = np.random.default_rng(2)
        c, m, r, n = 4, 96, 4, 72
        w0s = _mk(rng, (c, m, n))
        a, b = _mk(rng, (c, m, r)), _mk(rng, (c, r, n))
        w = jnp.asarray([0.4, 0.3, 0.0, 0.3], jnp.float32)
        out = perclient_fold(w0s, a, b, w, 2.0, interpret=True)
        expect = ref.perclient_fold_ref(w0s, a, b, w, 2.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-4)

    def test_perclient_fold_stacked_layers(self):
        rng = np.random.default_rng(3)
        c, L, m, r, n = 3, 2, 48, 4, 32
        w0s = _mk(rng, (c, L, m, n))
        a, b = _mk(rng, (c, L, m, r)), _mk(rng, (c, L, r, n))
        w = jnp.asarray(_rand_weights(rng, c), jnp.float32)
        out = perclient_fold(w0s, a, b, w, 1.0, interpret=True)
        for l in range(L):
            expect = ref.perclient_fold_ref(w0s[:, l], a[:, l], b[:, l], w,
                                            1.0)
            np.testing.assert_allclose(np.asarray(out[:, l]),
                                       np.asarray(expect),
                                       rtol=1e-5, atol=1e-4)


# --------------------------------------------------------------------------
# double-buffered round buffers
# --------------------------------------------------------------------------

class TestDoubleBuffering:
    def _template(self, rng):
        return {"blk": {"q": {"a": _mk(rng, (16, 4)), "b": _mk(rng, (4, 12))}}}

    def test_interleaved_rounds_stay_separate(self):
        """Round N+1 writes stream into their own ring set while round N is
        still open; take() pops FIFO and each round sees only its writes."""
        rng = np.random.default_rng(0)
        template = self._template(rng)
        bufs = RoundBuffers(template, 3, depth=2)
        trees = [self._template(np.random.default_rng(i + 1))
                 for i in range(5)]

        bufs.begin_round({0: 0, 1: 1, 2: 2}, round_id="N")
        bufs.write(0, trees[0], round_id="N")
        bufs.begin_round({1: 0, 3: 1}, round_id="N+1")  # N still open
        # interleave: N+1's write lands before N's remaining writes
        bufs.write(3, trees[3], round_id="N+1")
        bufs.write(2, trees[2], round_id="N")
        bufs.write(1, trees[1], round_id="N")
        bufs.write(1, trees[4], round_id="N+1")  # same client, other round

        assert bufs.open_rounds == ["N", "N+1"]
        assert bufs.delivered_in("N") == {0: 0, 2: 2, 1: 1}
        assert bufs.delivered_in("N+1") == {3: 1, 1: 0}

        stacks_n = bufs.take()  # FIFO → round N
        np.testing.assert_array_equal(
            np.asarray(stacks_n["blk/q/a"]),
            np.asarray(jnp.stack([t["blk"]["q"]["a"] for t in trees[:3]])))
        stacks_n1 = bufs.take()
        np.testing.assert_array_equal(np.asarray(stacks_n1["blk/q/a"][0]),
                                      np.asarray(trees[4]["blk"]["q"]["a"]))
        np.testing.assert_array_equal(np.asarray(stacks_n1["blk/q/a"][1]),
                                      np.asarray(trees[3]["blk"]["q"]["a"]))
        assert float(jnp.abs(stacks_n1["blk/q/a"][2]).max()) == 0.0

    def test_depth_exhaustion_raises_not_overwrites(self):
        rng = np.random.default_rng(1)
        bufs = RoundBuffers(self._template(rng), 2, depth=2)
        bufs.begin_round({0: 0}, round_id=0)
        bufs.begin_round({0: 0}, round_id=1)
        with pytest.raises(RuntimeError, match="in flight"):
            bufs.begin_round({0: 0}, round_id=2)
        bufs.take(0)  # close the oldest → a set frees up
        bufs.begin_round({0: 0}, round_id=2)
        with pytest.raises(ValueError, match="already open"):
            bufs.begin_round({1: 0}, round_id=2)

    def test_unknown_round_raises(self):
        rng = np.random.default_rng(2)
        bufs = RoundBuffers(self._template(rng), 2, depth=2)
        bufs.begin_round({0: 0}, round_id=5)
        with pytest.raises(KeyError, match="not open"):
            bufs.write_flat(0, {}, round_id=6)
        with pytest.raises(KeyError, match="not open"):
            bufs.take(6)

    def test_transport_routes_by_payload_round_id(self):
        """decode_into scatters each payload into the ring set its round_id
        names — two rounds' uplinks interleave without mixing."""
        from repro.fedsrv.transport import AdapterCodec

        rng = np.random.default_rng(3)
        template = self._template(rng)
        codec = AdapterCodec("none")
        bufs = RoundBuffers(template, 2, depth=2)
        bufs.begin_round({0: 0, 1: 1}, round_id=0)
        bufs.begin_round({0: 0, 2: 1}, round_id=1)
        t_a = self._template(np.random.default_rng(10))
        t_b = self._template(np.random.default_rng(11))
        codec.decode_into(codec.encode(t_b, round_id=1, client_id=0), bufs)
        codec.decode_into(codec.encode(t_a, round_id=0, client_id=0), bufs)
        s0 = bufs.take(0)
        s1 = bufs.take(1)
        np.testing.assert_array_equal(np.asarray(s0["blk/q/a"][0]),
                                      np.asarray(t_a["blk"]["q"]["a"]))
        np.testing.assert_array_equal(np.asarray(s1["blk/q/a"][0]),
                                      np.asarray(t_b["blk"]["q"]["a"]))


# --------------------------------------------------------------------------
# config validation
# --------------------------------------------------------------------------

class TestConfigValidation:
    def test_negative_svd_rank_rejected_at_config_time(self):
        with pytest.raises(ValueError, match="svd_rank"):
            FedConfig(svd_rank=-1)

    def test_bad_enums_rejected(self):
        with pytest.raises(ValueError, match="method"):
            FedConfig(method="fedavg")
        with pytest.raises(ValueError, match="assignment"):
            FedConfig(assignment="mean")
        with pytest.raises(ValueError, match="engine"):
            FedConfig(engine="cuda")

    def test_svd_rank_beyond_residual_bound_rejected(self):
        fed = FedConfig(num_clients=3, method="fedex_svd", svd_rank=13)
        with pytest.raises(ValueError, match="rank bound"):
            validate_fed_lora(fed, LoRAConfig(rank=4))
        # r' = k·r and r' = 0 (exact) are both fine
        validate_fed_lora(
            FedConfig(num_clients=3, method="fedex_svd", svd_rank=12),
            LoRAConfig(rank=4))
        validate_fed_lora(
            FedConfig(num_clients=3, method="fedex_svd", svd_rank=0),
            LoRAConfig(rank=4))


# --------------------------------------------------------------------------
# trainer integration: engine on/off parity for every new method
# --------------------------------------------------------------------------

class TestTrainerMethodParity:
    def _trainer(self, engine, rounds=1, **fed_kw):
        from repro.configs import (FedConfig, LoRAConfig, TrainConfig,
                                   get_config)
        from repro.core import FederatedTrainer
        from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
        from repro.models import build_model

        cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                                  vocab_size=16)
        model = build_model(cfg)
        ds = SyntheticLM(vocab=16, num_tasks=3, seed=0, concentration=0.05)
        seqs, labels = [], []
        for t in range(3):
            s = ds.sample(task=t, num_sequences=40, seq_len=32, seed=t)
            seqs.append(s)
            labels += [t] * 40
        seqs = np.concatenate(seqs)
        parts = dirichlet_partition(np.array(labels), 3, alpha=0.3, seed=0)
        loaders = [ClientLoader(seqs[p], batch_size=16, seed=i)
                   for i, p in enumerate(parts)]
        tr = FederatedTrainer(
            model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
            fed_cfg=FedConfig(num_clients=3, rounds=rounds, local_steps=2,
                              method=fed_kw.pop("method", "fedex"),
                              engine=engine, **fed_kw),
            train_cfg=TrainConfig(learning_rate=3e-2, schedule="constant"),
            client_loaders=loaders, eval_batches=[], seed=0)
        return tr, tr.run()

    def test_engine_attached_for_all_covered_methods(self):
        tr, _ = self._trainer("auto", method="fedex_svd", svd_rank=6)
        assert tr.engine is not None and tr.engine.method == "fedex_svd"
        tr, _ = self._trainer("auto", assignment="keep_local")
        assert tr.engine is not None and tr.engine.method == "keep_local"
        tr, _ = self._trainer("auto", assignment="reinit")
        assert tr.engine is not None and tr.engine.method == "reinit"
        # svd_rank=0 means exact → the plain fedex close
        tr, _ = self._trainer("auto", method="fedex_svd", svd_rank=0)
        assert tr.engine is not None and tr.engine.method == "fedex"

    def test_fedex_svd_parity_one_round(self):
        tr_on, h_on = self._trainer("auto", method="fedex_svd", svd_rank=6)
        tr_off, h_off = self._trainer("off", method="fedex_svd", svd_rank=6)
        _assert_close(tr_on.params, tr_off.params, tol=1e-4, msg="params")
        _assert_close(tr_on.global_lora, tr_off.global_lora, tol=1e-5,
                      msg="global")

    def test_keep_local_parity_one_round(self):
        tr_on, _ = self._trainer("auto", assignment="keep_local")
        tr_off, _ = self._trainer("off", assignment="keep_local")
        for i in range(3):
            _assert_close(tr_on.client_params[i], tr_off.client_params[i],
                          tol=1e-5, msg=f"client_params {i}")
        _assert_close(tr_on.global_lora, tr_off.global_lora, tol=1e-5,
                      msg="global")

    def test_reinit_parity_one_round(self):
        tr_on, _ = self._trainer("auto", assignment="reinit")
        tr_off, _ = self._trainer("off", assignment="reinit")
        _assert_close(tr_on.params, tr_off.params, tol=1e-5, msg="params")
        # the reinit'd adapters come from the same deterministic fold-in
        _assert_bitwise(tr_on.global_lora, tr_off.global_lora, "global")

    def test_async_buffer_commits_close_through_engine(self):
        """FedBuff-style buffered commits stream into the engine's ring and
        close through it — parity with the eager async path."""
        kw = dict(async_buffer=2, participation=0.7, rounds=3)
        tr_on, _ = self._trainer("auto", **kw)
        assert tr_on.engine is not None
        assert tr_on.coordinator.sink is tr_on.engine.buffers
        tr_off, _ = self._trainer("off", **kw)
        # per-commit closes differ by ulps (FMA contraction); over 3 commits
        # the difference feeds back through AdamW — same loose bound as the
        # cross-round sync parity test
        _assert_close(tr_on.params, tr_off.params, tol=1e-3, msg="params")
        _assert_close(tr_on.global_lora, tr_off.global_lora, tol=1e-3,
                      msg="global")
