"""Ring depth > 2, deadline eviction, and the deferred-divergence contract.

Contracts under test (core/engine.py, ISSUE 5 tentpole pieces 2–3):

* ``RoundBuffers`` generalizes past double buffering: ``depth`` rounds'
  writes interleave into separate ring sets and ``take()`` still pops
  strictly FIFO; exceeding ``depth`` without deadlines raises.
* Per-round deadlines: a FULL ring evicts expired rounds (``deadline ≤
  now``) instead of wedging — the FedBuff commit-lag regime — and uplinks
  arriving for an evicted round are DROPPED (returns False), never scattered
  into a live round or raised as unroutable.
* Deferred divergence: the engine close performs NO host sync — the
  divergence comes back as an unresolved ``DeferredDivergence`` device
  handle (asserted under ``jax.transfer_guard_device_to_host`` — a no-op on
  CPU where arrays are host-resident, enforcing on accelerators — plus
  structurally), and the trainer resolves every handle by the round
  boundary / end of ``run()``.
* Ring/lag config threads through: ``FedConfig.ring_depth`` reaches the
  engine's buffers, ``ring_max_lag`` the async coordinator, and invalid
  values are rejected at config time.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core.engine import (DeferredDivergence, RoundBuffers,
                               RoundCloseEngine)
from repro.util.tree import flatten_with_paths


def _template(m=6, r=2, n=4):
    return {"blk": {"q_proj": {"a": jnp.zeros((m, r)),
                               "b": jnp.zeros((r, n))}}}


def _lora(val, m=6, r=2, n=4):
    return {"blk": {"q_proj": {"a": jnp.full((m, r), float(val)),
                               "b": jnp.full((r, n), float(val))}}}


class TestRingDepth:
    def test_depth3_rotation_fifo(self):
        """Three rounds' writes interleave into distinct sets; take() pops
        oldest-first and hands each round exactly its own deliveries."""
        bufs = RoundBuffers(_template(), c_max=2, depth=3)
        for rnd in range(3):
            bufs.begin_round({0: 0, 1: 1}, round_id=rnd)
        # interleaved writes across all three open rounds
        for rnd in (2, 0, 1):
            bufs.write(0, _lora(10 * rnd + 1), round_id=rnd)
            bufs.write(1, _lora(10 * rnd + 2), round_id=rnd)
        assert bufs.open_rounds == [0, 1, 2]
        for rnd in range(3):
            stacks = bufs.take()
            got = float(stacks["blk/q_proj/a"][0, 0, 0])
            assert got == 10 * rnd + 1, f"round {rnd} got set of {got}"
        assert bufs.open_rounds == []

    def test_depth_exhaustion_without_deadlines_raises(self):
        bufs = RoundBuffers(_template(), c_max=1, depth=3)
        for rnd in range(3):
            bufs.begin_round({0: 0}, round_id=rnd)
        with pytest.raises(RuntimeError, match="in flight"):
            bufs.begin_round({0: 0}, round_id=3)
        # even with `now`, un-deadlined rounds are never evicted implicitly
        with pytest.raises(RuntimeError, match="in flight"):
            bufs.begin_round({0: 0}, round_id=3, now=1e9)

    def test_deeper_ring_accepts_more_open_rounds(self):
        bufs = RoundBuffers(_template(), c_max=1, depth=5)
        for rnd in range(5):
            bufs.begin_round({0: 0}, round_id=rnd)
        assert len(bufs.open_rounds) == 5


class TestDeadlineEviction:
    def test_full_ring_evicts_expired_round(self):
        """FedBuff regime: the round lagging past its deadline is evicted
        from a full ring; the fresh round opens; FIFO continues with the
        surviving round."""
        bufs = RoundBuffers(_template(), c_max=1, depth=2)
        bufs.begin_round({0: 0}, round_id="r0", deadline=5.0)
        bufs.begin_round({0: 0}, round_id="r1", deadline=50.0)
        bufs.write(0, _lora(1), round_id="r1")
        # ring full; r0 expired at now=6 → evicted, r2 opens
        bufs.begin_round({0: 0}, round_id="r2", deadline=60.0, now=6.0)
        assert bufs.open_rounds == ["r1", "r2"]
        assert bufs.evictions == 1
        stacks = bufs.take()
        assert float(stacks["blk/q_proj/a"][0, 0, 0]) == 1.0  # r1's data

    def test_unexpired_rounds_survive_a_full_ring(self):
        bufs = RoundBuffers(_template(), c_max=1, depth=2)
        bufs.begin_round({0: 0}, round_id="r0", deadline=100.0)
        bufs.begin_round({0: 0}, round_id="r1", deadline=100.0)
        with pytest.raises(RuntimeError, match="in flight"):
            bufs.begin_round({0: 0}, round_id="r2", now=6.0)

    def test_stale_uplink_for_evicted_round_is_dropped(self):
        """A commit lagging a full version (≥ max_version_lag): its set is
        evicted, and
        the late uplink is discarded — not scattered, not an error."""
        bufs = RoundBuffers(_template(), c_max=1, depth=2)
        bufs.begin_round({0: 0}, round_id="v0", deadline=1)  # versions scale
        bufs.begin_round({0: 0}, round_id="v1", deadline=3)
        bufs.begin_round({0: 0}, round_id="v2", deadline=4, now=2)  # evicts v0
        assert "v0" not in bufs.open_rounds
        assert bufs.write(0, _lora(7), round_id="v0") is False  # dropped
        assert bufs.write(0, _lora(8), round_id="v1") is True
        stacks = bufs.take("v1")
        assert float(stacks["blk/q_proj/a"][0, 0, 0]) == 8.0
        # an unknown (never-opened / long-closed) round still raises
        with pytest.raises(KeyError):
            bufs.write(0, _lora(9), round_id="never-opened")

    def test_explicit_evict_returns_delivered_lanes(self):
        bufs = RoundBuffers(_template(), c_max=2, depth=2)
        bufs.begin_round({0: 0, 1: 1}, round_id="r0")
        bufs.write(1, _lora(3), round_id="r0")
        assert bufs.evict("r0") == {1: 1}
        with pytest.raises(RuntimeError, match="no open round"):
            bufs.take()

    def test_evicted_ids_memory_is_bounded(self):
        bufs = RoundBuffers(_template(), c_max=1, depth=1)
        for i in range(80):
            bufs.begin_round({0: 0}, round_id=i)
            bufs.evict(i)
        assert len(bufs._evicted) <= 64


def _small_engine(c=3, m=8, r=2, n=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    params = {"blk": {"q_proj": {"kernel": mk((m, n))}}}
    template = {"blk": {"q_proj": {"a": mk((m, r)), "b": mk((r, n))}}}
    loras = [{"blk": {"q_proj": {"a": mk((m, r)), "b": mk((r, n))}}}
             for _ in range(c)]
    eng = RoundCloseEngine(params, template, c_max=c, scale=2.0,
                           backend="jnp", **kw)
    return eng, params, loras


class TestDeferredDivergence:
    def test_close_returns_unresolved_device_handle(self):
        """No host sync inside the close: the divergence handle is an
        unresolved device scalar. On accelerators the transfer guard would
        fault any device→host copy inside this block."""
        eng, params, loras = _small_engine()
        eng.buffers.begin_round({i: i for i in range(3)}, round_id=0)
        for i, l in enumerate(loras):
            eng.buffers.write(i, l, round_id=0)
        with jax.transfer_guard_device_to_host("disallow"):
            _, _, div = eng.close(params, [0, 1, 2], round_id=0)
        assert isinstance(div, DeferredDivergence)
        assert not div.resolved
        assert isinstance(div.raw, jax.Array)
        assert div.round_id == 0
        val = div.resolve()  # the round-boundary host sync
        assert div.resolved and div.raw is None
        assert isinstance(val, float) and val > 0

    def test_handle_quacks_like_a_float(self):
        eng, params, loras = _small_engine()
        eng.buffers.begin_round({i: i for i in range(3)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        _, _, div = eng.close(params, [0, 1, 2])
        assert div > 0 and div >= 0 and not (div < 0)
        assert abs(div - float(div)) == 0
        np.testing.assert_allclose(np.asarray(div), float(div))
        assert "resolved" in repr(div)

    def test_keep_local_close_is_deferred_too(self):
        eng, params, loras = _small_engine(method="keep_local")
        eng.buffers.begin_round({i: i for i in range(3)})
        for i, l in enumerate(loras):
            eng.buffers.write(i, l)
        with jax.transfer_guard_device_to_host("disallow"):
            _, div = eng.close_keep_local([params] * 3, [0, 1, 2])
        assert isinstance(div, DeferredDivergence) and not div.resolved

    def test_engine_threads_ring_depth(self):
        eng, *_ = _small_engine(depth=4)
        assert eng.buffers.depth == 4


class TestConfigThreading:
    def test_fedconfig_validates_ring_fields(self):
        with pytest.raises(ValueError, match="ring_depth"):
            FedConfig(ring_depth=0)
        with pytest.raises(ValueError, match="ring_max_lag"):
            FedConfig(ring_max_lag=0)

    def test_async_coordinator_validates_lag(self):
        from repro.fedsrv import (AdapterCodec, AsyncBufferCoordinator,
                                  BytesLedger, ClientInfo, ClientRegistry)
        registry = ClientRegistry([ClientInfo(client_id=0, num_examples=1)])
        with pytest.raises(ValueError, match="max_version_lag"):
            AsyncBufferCoordinator(registry, max_version_lag=0)

    def test_trainer_ring_depth_parity(self):
        """A deeper ring changes scheduling capacity, never the math: the
        same run with ring_depth 2 vs 3 produces identical histories, and
        every divergence handle is resolved by run()'s return."""
        cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                                  vocab_size=16)
        from repro.core import FederatedTrainer
        from repro.data import ClientLoader, SyntheticLM
        from repro.models import build_model

        ds = SyntheticLM(vocab=16, num_tasks=3, seed=0)
        hists = []
        for depth in (2, 3):
            # fresh loaders per run: identical batch streams for both depths
            loaders = [ClientLoader(ds.sample(task=t, num_sequences=12,
                                              seq_len=16, seed=t),
                                    batch_size=4, seed=t) for t in range(3)]
            tr = FederatedTrainer(
                model=build_model(cfg), lora_cfg=LoRAConfig(rank=4, alpha=8),
                fed_cfg=FedConfig(num_clients=3, rounds=2, local_steps=2,
                                  method="fedex", ring_depth=depth),
                train_cfg=TrainConfig(learning_rate=1e-2,
                                      schedule="constant"),
                client_loaders=loaders, eval_batches=[], seed=0)
            assert tr.engine.buffers.depth == depth
            hists.append(tr.run())
        for a, b in zip(*hists):
            assert isinstance(a.divergence_scaled, float)
            assert a.divergence_scaled == b.divergence_scaled
            assert a.client_losses == b.client_losses
