"""Seeded fault injection + the defended uplink path.

Contracts under test (robustness tentpole):

* the fault DSL parses, round-trips, and rejects junk at config time;
* per-purpose rng streams: latency/dropout/fault draws come from disjoint
  deterministic streams, so a dropped client never shifts another client's
  fault coin and a fault plan never perturbs the clean clients;
* every DETECTABLE payload corruption (NaN, Inf, truncated wire buffer) is
  quarantined by the validation stage with a typed, context-carrying
  ``TransportError``; byzantine scaling is caught iff ``max_norm`` is set;
* transient decode failures retry with bounded backoff and degrade to a
  quarantine past the limit;
* the acceptance bar: a C=8 sync round under NaN + truncate + replay faults
  closes **bitwise identical** to its crash-twin (same seed, faulty clients
  absent) for fedex, fedex_svd, and the keep_local assignment;
* all-lanes-quarantined rounds degrade gracefully (sync, async, and mesh —
  where quarantined lanes must be ZEROED, not just zero-weighted, because
  ``0·NaN = NaN``);
* the ring drops replayed/duplicate addresses and survives id reuse after
  wrap; the BytesLedger buckets faulty bytes under ``quarantined``/
  ``dropped`` so ``reconcile()`` stays honest.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import FederatedTrainer
from repro.core.engine import RoundBuffers
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.fedsrv import (AdapterCodec, FaultInjector, FaultPlan,
                          StaleUplinkError, TransportError, ValidationPolicy,
                          purpose_rng)
from repro.fedsrv.faults import DETECTABLE_KINDS, FAULT_STREAM
from repro.fedsrv.registry import DROPOUT_STREAM
from repro.fedsrv.transport import BytesLedger
from repro.models import build_model
from repro.util.tree import flatten_with_paths


def _tree(seed=0, m=16, r=4, n=12):
    rng = np.random.default_rng(seed)
    return {"l": {"q_proj": {
        "a": jnp.asarray(rng.normal(size=(m, r)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(r, n)), jnp.float32)}}}


def _corrupted_payload(plan_text, *, client_id=0, round_id=0, codec=None):
    codec = codec or AdapterCodec("none")
    payload = codec.encode(_tree(), round_id=round_id, client_id=client_id)
    inj = FaultInjector(FaultPlan.parse(plan_text))
    payload, applied = inj.corrupt(payload)
    return codec, payload, applied


class TestFaultDSL:
    def test_parse_fields(self):
        plan = FaultPlan.parse(
            "nan@0.5(clients=1+3,rounds=0+2);scale@1(factor=100);"
            "replay@1(offset=2)", seed=7)
        assert plan.seed == 7
        nan, scale, replay = plan.specs
        assert (nan.kind, nan.prob) == ("nan", 0.5)
        assert nan.clients == (1, 3) and nan.rounds == (0, 2)
        assert scale.kind == "scale" and scale.factor == 100.0
        assert scale.clients is None  # every client
        assert replay.offset == 2

    def test_str_round_trip(self):
        text = "nan@0.5(clients=1+3);truncate@1(rounds=2);crash@0.25"
        plan = FaultPlan.parse(text)
        assert FaultPlan.parse(str(plan)).specs == plan.specs

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultPlan.parse("gremlin@1")

    def test_fedconfig_parses_plan_at_config_time(self):
        FedConfig(num_clients=2, rounds=1, faults="nan@1(clients=0)")
        with pytest.raises(ValueError):
            FedConfig(num_clients=2, rounds=1, faults="gremlin@1")


class TestPurposeStreams:
    def test_streams_deterministic_and_disjoint(self):
        a = purpose_rng(3, 1, 2, FAULT_STREAM, 0).integers(1 << 30)
        b = purpose_rng(3, 1, 2, FAULT_STREAM, 0).integers(1 << 30)
        assert a == b
        latency = purpose_rng(3, 1, 2).integers(1 << 30)
        dropout = purpose_rng(3, 1, 2, DROPOUT_STREAM).integers(1 << 30)
        assert len({int(a), int(latency), int(dropout)}) == 3

    def test_other_clients_do_not_shift_fault_draws(self):
        """The fault coin for (round, client) is a pure function of the
        seed — querying (or skipping) other clients cannot move it."""
        plan = FaultPlan.parse("nan@0.5", seed=11)
        full, sparse = FaultInjector(plan), FaultInjector(plan)
        want = {}
        for cid in range(6):
            want[cid] = [i for i, _ in full.draws(0, cid)]
        assert want[5] == [i for i, _ in sparse.draws(0, 5)]
        assert want[2] == [i for i, _ in sparse.draws(0, 2)]

    def test_prob_one_skips_the_coin(self):
        """prob ≥ 1 activates without consuming a draw — plans written with
        @1 stay stable if a probabilistic spec is added alongside."""
        always = FaultInjector(FaultPlan.parse("nan@1(clients=0)", seed=0))
        assert [s.kind for _, s in always.draws(0, 0)] == ["nan"]


class TestCorruptionDetection:
    @pytest.mark.parametrize("kind,reason", [
        ("nan", "nonfinite"), ("inf", "nonfinite"), ("truncate", "bytes")])
    def test_detectable_kinds_quarantined(self, kind, reason):
        codec, payload, applied = _corrupted_payload(
            f"{kind}@1(clients=0)", client_id=0)
        assert [s.kind for s in applied] == [kind]
        with pytest.raises(TransportError) as ei:
            codec.decode(payload)
        assert ei.value.reason == reason
        assert ei.value.client_id == 0 and ei.value.round_id == 0

    def test_detectable_kinds_is_exactly_these(self):
        assert set(DETECTABLE_KINDS) == {"nan", "inf", "truncate"}

    def test_scale_needs_norm_limit(self):
        codec, payload, _ = _corrupted_payload("scale@1(factor=1e6)")
        codec.decode(payload)  # max_norm=0: byzantine scaling passes
        armed = AdapterCodec("none",
                             validation=ValidationPolicy(max_norm=100.0))
        with pytest.raises(TransportError) as ei:
            armed.decode(payload)
        assert ei.value.reason == "norm"

    def test_replay_rewrites_round_id(self):
        codec, payload, _ = _corrupted_payload(
            "replay@1(offset=2)", round_id=5)
        assert payload.round_id == 3  # rewound; addressing will refuse it

    def test_spec_and_shape_validation(self):
        codec = AdapterCodec("none")
        codec.register_spec(_tree())
        extra = dict(_tree())
        extra["rogue"] = {"a": jnp.zeros((2, 2))}
        with pytest.raises(TransportError) as ei:
            codec.decode(codec.encode(extra, round_id=0, client_id=1))
        assert ei.value.reason == "spec"
        with pytest.raises(TransportError) as ei:
            codec.decode(codec.encode(_tree(m=8), round_id=0, client_id=1))
        assert ei.value.reason == "shape"

    def test_clean_payload_passes_registered_spec(self):
        codec = AdapterCodec("none")
        codec.register_spec(_tree())
        out = codec.decode(codec.encode(_tree(seed=3), round_id=0,
                                        client_id=1))
        for k, v in flatten_with_paths(_tree(seed=3)).items():
            np.testing.assert_array_equal(np.asarray(v),
                                          flatten_with_paths(out)[k])


def _trainer(fed_cfg, clients=4, vocab=16, seed=0, schedule="constant"):
    cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                              vocab_size=vocab)
    model = build_model(cfg)
    ds = SyntheticLM(vocab=vocab, num_tasks=clients, seed=seed)
    seqs, labels = [], []
    for t in range(clients):
        n = 30 + 20 * t  # unequal shards → non-uniform example weights
        seqs.append(ds.sample(task=t, num_sequences=n, seq_len=32,
                              seed=seed + t))
        labels += [t] * n
    seqs = np.concatenate(seqs)
    parts = dirichlet_partition(np.array(labels), clients, alpha=0.5,
                                seed=seed)
    loaders = [ClientLoader(seqs[p], batch_size=8, seed=seed + i)
               for i, p in enumerate(parts)]
    return FederatedTrainer(
        model=model, lora_cfg=LoRAConfig(rank=4, alpha=8), fed_cfg=fed_cfg,
        train_cfg=TrainConfig(learning_rate=1e-2, schedule=schedule),
        client_loaders=loaders, eval_batches=[], seed=seed)


def _leaves(tr):
    return [np.asarray(x) for x in jax.tree.leaves((tr.global_lora,
                                                    tr.params))]


class TestRetryBackoff:
    def test_transient_decode_retries_then_delivers(self):
        tr = _trainer(FedConfig(
            num_clients=3, rounds=1, local_steps=1, method="fedex",
            participation=1.0, faults="decode_error@1(clients=0,count=1)",
            uplink_retries=2))
        tr.run()
        out = tr.outcomes[0]
        assert out.retries >= 1
        assert 0 in out.client_ids  # recovered, not quarantined
        assert not out.quarantined

    def test_retries_exhausted_quarantines(self):
        tr = _trainer(FedConfig(
            num_clients=3, rounds=1, local_steps=1, method="fedex",
            participation=1.0, faults="decode_error@1(clients=0,count=5)",
            uplink_retries=1))
        tr.run()
        out = tr.outcomes[0]
        assert (0, "retries_exhausted") in out.quarantined
        assert 0 not in out.client_ids


PLAN = "nan@1(clients=2);truncate@1(clients=5);replay@1(clients=7)"
TWIN = "crash@1(clients=2+5+7)"


class TestCrashTwinExactness:
    """The acceptance bar: faulty clients contribute NOTHING — the close is
    bitwise identical to the same-seed run where they simply crashed."""

    @pytest.mark.parametrize("method,extra", [
        ("fedex", {}),
        ("fedex_svd", {"svd_rank": 8}),
        ("fedex", {"assignment": "keep_local"}),
    ], ids=["fedex", "fedex_svd", "keep_local"])
    def test_c8_sync_round_bitwise(self, method, extra):
        def run(plan):
            tr = _trainer(FedConfig(
                num_clients=8, rounds=2, local_steps=1, method=method,
                participation=1.0, weighting="examples", engine="auto",
                faults=plan, **extra), clients=8)
            tr.run()
            return tr

        faulty, twin = run(PLAN), run(TWIN)
        assert {c for c, _ in faulty.outcomes[0].quarantined} == {2, 5, 7}
        assert sorted(faulty.outcomes[0].client_ids) \
            == sorted(twin.outcomes[0].client_ids)
        for a, b in zip(_leaves(faulty), _leaves(twin)):
            np.testing.assert_array_equal(a, b)

    def test_faulty_run_is_deterministic(self):
        runs = [_trainer(FedConfig(
            num_clients=4, rounds=1, local_steps=1, method="fedex",
            participation=1.0, faults="nan@0.5;truncate@0.5"))
            for _ in range(2)]
        for tr in runs:
            tr.run()
        assert runs[0].outcomes[0].quarantined \
            == runs[1].outcomes[0].quarantined
        for a, b in zip(_leaves(runs[0]), _leaves(runs[1])):
            np.testing.assert_array_equal(a, b)

    def test_dropout_does_not_shift_fault_coins(self):
        """Adding dropout changes WHO uplinks, never which surviving
        uplinks get faulted — disjoint rng streams."""
        def quarantined(dropout):
            tr = _trainer(FedConfig(
                num_clients=6, rounds=2, local_steps=1, method="fedex",
                participation=1.0, dropout_prob=dropout,
                faults="nan@1(clients=1+4)"), clients=6)
            tr.run()
            return [{c for c, _ in o.quarantined} - set(o.dropped_out)
                    for o in tr.outcomes]

        base, dropped = quarantined(0.0), quarantined(0.4)
        for rnd in range(2):
            assert dropped[rnd] <= base[rnd]  # only dropouts differ


HET_RANKS = (4, 2, 1, 4, 2, 1, 4, 2)  # faulted clients 2, 5, 7 are ragged


class TestHeteroCrashTwin:
    """Ragged-rank chaos: under faults the hetero uplinks ride the SAME
    defended codec path as the uniform methods, so a quarantined ragged
    lane contributes NOTHING — the close is bitwise identical to the
    crash twin, per-client bases and rank-r_i adapters included."""

    def _run(self, plan):
        tr = _trainer(FedConfig(
            num_clients=8, rounds=2, local_steps=1, method="hetero",
            client_ranks=HET_RANKS, participation=1.0, engine="auto",
            faults=plan), clients=8)
        tr.run()
        return tr

    def test_c8_hetero_round_bitwise(self):
        faulty, twin = self._run(PLAN), self._run(TWIN)
        # ledger buckets: nan + truncate quarantine, replay drops, and the
        # twin's crashes drop — same survivor subset both runs
        q = {e.client_id for e in faulty.ledger.entries
             if e.direction == "quarantined"}
        d = {e.client_id for e in faulty.ledger.entries
             if e.direction == "dropped"}
        assert q == {2, 5} and 7 in d
        assert {e.client_id for e in twin.ledger.entries
                if e.direction == "dropped"} == {2, 5, 7}
        for a, b in zip(_leaves(faulty), _leaves(twin)):
            np.testing.assert_array_equal(a, b)
        fa = jax.tree.leaves((faulty.client_params, faulty._client_lora))
        fb = jax.tree.leaves((twin.client_params, twin._client_lora))
        assert fa and len(fa) == len(fb)
        for a, b in zip(fa, fb):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_survivor_loras_keep_true_rank(self):
        tr = self._run(PLAN)
        for c, r in enumerate(HET_RANKS):
            widths = [np.shape(v)[-1]
                      for k, v in
                      flatten_with_paths(tr._client_lora[c]).items()
                      if k.endswith("/a")]
            assert widths and all(w == r for w in widths)

    def test_hetero_faulty_run_is_deterministic(self):
        runs = [self._run(PLAN) for _ in range(2)]
        for a, b in zip(_leaves(runs[0]), _leaves(runs[1])):
            np.testing.assert_array_equal(a, b)


class TestDegradedRounds:
    def test_sync_all_quarantined_carries_global_forward(self):
        tr = _trainer(FedConfig(
            num_clients=3, rounds=2, local_steps=1, method="fedex",
            participation=1.0, faults="nan@1(rounds=0)"))
        before = _leaves(tr)
        hist = tr.run()
        out = tr.outcomes[0]
        assert out.degraded and not out.delivered
        assert {c for c, _ in out.quarantined} == {0, 1, 2}
        # round 1 recovered: clean uplinks, global moved
        assert tr.outcomes[1].delivered and not tr.outcomes[1].degraded
        assert len(hist) == 2
        for leaf in _leaves(tr):
            assert np.isfinite(leaf).all()
        # the degraded round itself changed nothing
        tr2 = _trainer(FedConfig(
            num_clients=3, rounds=1, local_steps=1, method="fedex",
            participation=1.0, faults="nan@1(rounds=0)"))
        tr2.run()
        for a, b in zip(before, _leaves(tr2)):
            np.testing.assert_array_equal(a, b)

    def test_async_all_quarantined_holds_version(self):
        tr = _trainer(FedConfig(
            num_clients=3, rounds=2, local_steps=1, method="fedex",
            async_buffer=2, faults="nan@1(rounds=0)"))
        tr.run()
        assert tr.outcomes[0].degraded and not tr.outcomes[0].delivered
        assert not tr.outcomes[1].degraded
        for leaf in _leaves(tr):
            assert np.isfinite(leaf).all()

    def test_mesh_quarantine_zeroes_lane_not_just_weight(self):
        """Regression: a NaN lane must be ZEROED before the mesh close —
        zero-weight masking alone leaks NaN (0·NaN = NaN)."""
        from repro.launch.mesh_train import MeshFederatedTrainer

        cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                                  vocab_size=16)
        model = build_model(cfg)
        ds = SyntheticLM(vocab=16, num_tasks=3, seed=0)
        loaders = [ClientLoader(
            ds.sample(task=t, num_sequences=12, seq_len=16, seed=t),
            batch_size=4, seed=t) for t in range(3)]
        evals = [ds.to_batch(ds.sample(task=0, num_sequences=8, seq_len=16,
                                       seed=100))]
        tr = MeshFederatedTrainer(
            model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
            fed_cfg=FedConfig(num_clients=3, rounds=2, local_steps=1,
                              method="fedex", participation=1.0,
                              weighting="examples",
                              faults="nan@1(clients=1)"),
            train_cfg=TrainConfig(learning_rate=1e-2, schedule="constant"),
            client_loaders=loaders, eval_batches=evals, seed=0)
        hist = tr.run()
        assert len(hist) == 2
        for rec in hist:
            assert np.isfinite(rec.eval_loss)
        for leaf in jax.tree.leaves((tr.global_lora, tr.params)):
            assert np.isfinite(np.asarray(leaf)).all()


def _flat(val, m=6, r=2, n=4):
    return flatten_with_paths(
        {"blk": {"q_proj": {"a": jnp.full((m, r), float(val)),
                            "b": jnp.full((r, n), float(val))}}})


def _ring_template(m=6, r=2, n=4):
    return {"blk": {"q_proj": {"a": jnp.zeros((m, r)),
                               "b": jnp.zeros((r, n))}}}


class TestRingFaultEdges:
    def test_duplicate_lane_write_dropped(self):
        bufs = RoundBuffers(_ring_template(), c_max=2, depth=2)
        bufs.begin_round({0: 0, 1: 1}, round_id=0)
        assert bufs.write_flat(0, _flat(1.0), round_id=0)
        assert not bufs.write_flat(0, _flat(9.0), round_id=0)
        assert bufs.duplicate_drops == 1
        stacks = bufs.take()
        assert float(stacks["blk/q_proj/a"][0, 0, 0]) == 1.0  # first write won

    def test_write_after_eviction_dropped_not_duplicate(self):
        bufs = RoundBuffers(_ring_template(), c_max=2, depth=2)
        bufs.begin_round({0: 0}, round_id=0)
        bufs.evict(0)
        assert not bufs.write_flat(0, _flat(1.0), round_id=0)
        assert not bufs.write_flat(0, _flat(1.0), round_id=0)
        assert bufs.duplicate_drops == 0  # stale, not a duplicate lane

    def test_replay_races_begin_round_after_wrap(self):
        """A replayed uplink for a CLOSED round id must be refused even
        while the ring wraps — and a legitimate id reuse (begin_round with
        the same id much later) starts clean."""
        bufs = RoundBuffers(_ring_template(), c_max=1, depth=2)
        bufs.begin_round({7: 0}, round_id=0)
        bufs.write_flat(7, _flat(1.0), round_id=0)
        bufs.take()  # round 0 closed
        bufs.begin_round({7: 0}, round_id=1)
        bufs.begin_round({7: 0}, round_id=2)  # ring wrapped past round 0
        drops = bufs.replay_drops
        assert not bufs.write_flat(7, _flat(6.0), round_id=0)  # replay
        assert bufs.replay_drops == drops + 1
        bufs.take()
        bufs.take()
        bufs.begin_round({7: 0}, round_id=0)  # id reuse: fresh round
        assert bufs.write_flat(7, _flat(3.0), round_id=0)
        assert float(bufs.take()["blk/q_proj/a"][0, 0, 0]) == 3.0


class TestLedgerDirections:
    def test_fault_directions_bucketed_separately(self):
        codec = AdapterCodec("none")
        ledger = BytesLedger()
        p = codec.encode(_tree(), round_id=0, client_id=1)
        ledger.record(p, direction="quarantined", note="nonfinite")
        tot = ledger.round_totals(0)
        assert tot["quarantined_params"] == p.num_params
        assert tot["uplink_params"] == 0  # faulty bytes never hide here

    def test_reclassify_downlink_of_quarantined_client(self):
        codec = AdapterCodec("none")
        ledger = BytesLedger()
        down = codec.encode(_tree(), round_id=0, client_id=1,
                            direction="downlink")
        ledger.record(down)
        assert ledger.reclassify(0, 1, "downlink", "dropped", note="q")
        tot = ledger.round_totals(0)
        assert tot["downlink_params"] == 0
        assert tot["dropped_params"] == down.num_params
        assert not ledger.reclassify(0, 9, "downlink", "dropped")

    def test_trainer_ledger_reconciles_with_quarantine(self):
        """End-to-end: the faulty round's ledger carries quarantined bytes
        AND still reconciles delivered params against the analytic form."""
        tr = _trainer(FedConfig(
            num_clients=4, rounds=1, local_steps=1, method="fedex",
            participation=1.0, faults="nan@1(clients=1)"))
        tr.run()
        tot = tr.ledger.round_totals(0)
        assert tot.get("quarantined_params", 0) > 0
        assert tot["uplink_params"] > 0
        # per-client uplink params are equal ⇒ delivered = 3 of 4 shares
        assert tot["uplink_params"] * 1 == tot["quarantined_params"] * 3
