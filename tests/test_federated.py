"""Integration tests: the federated trainer end-to-end on a learnable task."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import FederatedTrainer, fedex_aggregate, merge_lora, product_mean
from repro.core.aggregation import apply_residual
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.models import build_model
from repro.util.tree import flatten_with_paths


def _setup(vocab=16, clients=3, batch=16, seq=32, alpha=0.3, seed=0):
    cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                              vocab_size=vocab)
    model = build_model(cfg)
    ds = SyntheticLM(vocab=vocab, num_tasks=clients, seed=seed, concentration=0.05)
    seqs, labels = [], []
    for t in range(clients):
        s = ds.sample(task=t, num_sequences=60, seq_len=seq, seed=seed + t)
        seqs.append(s)
        labels += [t] * 60
    seqs = np.concatenate(seqs)
    parts = dirichlet_partition(np.array(labels), clients, alpha=alpha, seed=seed)
    loaders = [ClientLoader(seqs[p], batch_size=batch, seed=seed + i)
               for i, p in enumerate(parts)]
    evals = [ds.to_batch(ds.sample(task=t, num_sequences=16, seq_len=seq,
                                   seed=seed + 100 + t)) for t in range(clients)]
    return cfg, model, loaders, evals


def _run(method, rounds=4, local_steps=12, assignment="average", **kw):
    cfg, model, loaders, evals = _setup(**kw)
    tr = FederatedTrainer(
        model=model, lora_cfg=LoRAConfig(rank=8, alpha=16, include_mlp=True),
        fed_cfg=FedConfig(num_clients=3, rounds=rounds, local_steps=local_steps,
                          method=method, assignment=assignment, svd_rank=6),
        train_cfg=TrainConfig(learning_rate=3e-2, schedule="constant"),
        client_loaders=loaders, eval_batches=evals, seed=0)
    return tr, tr.run()


class TestTraining:
    def test_fedex_learns_below_uniform(self):
        tr, hist = _run("fedex", rounds=4, local_steps=25)
        uniform = np.log(16)
        assert hist[-1].eval_loss < uniform - 0.25, (
            f"no learning: eval {hist[-1].eval_loss} vs uniform {uniform}")

    def test_fedex_divergence_positive_pre_aggregation(self):
        """Clients DO diverge during local training (Fig 2's premise)…"""
        tr, hist = _run("fedex", rounds=2)
        assert hist[-1].divergence_scaled > 0

    def test_ffa_freezes_a(self):
        tr, hist = _run("ffa", rounds=2, local_steps=4)
        # a must equal its init value (frozen); with shared init this is
        # equivalent across clients — check b moved but a didn't.
        flat = flatten_with_paths(tr.global_lora)
        for path, leaf in flat.items():
            if path.endswith("/b"):
                assert float(jnp.abs(leaf).max()) > 0, "b never trained"
        # divergence for ffa is ~0 (exact by construction)
        assert hist[-1].divergence_scaled < 1e-6

    @pytest.mark.parametrize("method", ["fedit", "fedex_svd", "centralized"])
    def test_other_methods_run(self, method):
        tr, hist = _run(method, rounds=2, local_steps=4)
        assert all(np.isfinite(r.eval_loss) for r in hist)

    @pytest.mark.parametrize("assignment", ["keep_local", "reinit"])
    def test_assignment_strategies_run(self, assignment):
        tr, hist = _run("fedex", rounds=2, local_steps=4, assignment=assignment)
        assert all(np.isfinite(r.eval_loss) for r in hist)


class TestRoundExactness:
    def test_fedex_round_is_exact_end_to_end(self):
        """After a REAL training round, the FedEx server state satisfies
        W0' + scale·āb̄ == W0 + scale·mean(aᵢbᵢ) — Eq. 7–9 with live grads."""
        cfg, model, loaders, evals = _setup()
        from repro.core import init_lora
        from repro.core.federated import make_local_step
        from repro.optim import init_adamw

        params = model.init(jax.random.key(0))
        lcfg = LoRAConfig(rank=4, alpha=8)
        lora0 = init_lora(jax.random.key(1), params, cfg, lcfg)
        step = make_local_step(model, lcfg.scale, TrainConfig(learning_rate=1e-2))

        client_loras = []
        for c in range(3):
            lora = lora0
            opt = init_adamw(lora)
            for _ in range(5):
                lora, opt, _, _ = step(params, lora, opt,
                                       loaders[c].next_batch(), 1e-2)
            client_loras.append(lora)

        g, res = fedex_aggregate(client_loras)
        params_after = apply_residual(params, res, lcfg.scale)
        w_fedex = merge_lora(params_after, g, lcfg.scale)
        ideal_update = product_mean(client_loras)
        w_ideal = apply_residual(params, ideal_update, lcfg.scale)
        fa = flatten_with_paths(w_fedex)
        fb = flatten_with_paths(w_ideal)
        for k in fa:
            np.testing.assert_allclose(np.asarray(fa[k]), np.asarray(fb[k]),
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"mismatch at {k}")

    def test_fedex_beats_fedit_divergence(self):
        """Post-aggregation deviation: FedEx ≡ 0 by construction, FedIT > 0."""
        cfg, model, loaders, _ = _setup()
        from repro.core import init_lora, mean_deviation
        from repro.core.federated import make_local_step
        from repro.optim import init_adamw

        params = model.init(jax.random.key(0))
        lcfg = LoRAConfig(rank=4, alpha=8)
        lora0 = init_lora(jax.random.key(1), params, cfg, lcfg)
        step = make_local_step(model, lcfg.scale, TrainConfig(learning_rate=1e-2))
        client_loras = []
        for c in range(3):
            lora, opt = lora0, init_adamw(lora0)
            for _ in range(5):
                lora, opt, _, _ = step(params, lora, opt,
                                       loaders[c].next_batch(), 1e-2)
            client_loras.append(lora)
        assert mean_deviation(client_loras) > 0
        # after FedEx assignment all clients share identical adapters → dev 0
        g, _ = fedex_aggregate(client_loras)
        assert mean_deviation([g, g, g]) < 1e-7


class TestFusedFold:
    def test_pallas_fold_matches_host_path(self):
        """apply_residual_fused (Pallas kernel) ≡ fedex_residual + apply_residual
        on a REAL model parameter tree with stacked layers."""
        import dataclasses
        from repro.core import apply_residual_fused, fedex_residual, init_lora
        from repro.core.aggregation import apply_residual as host_apply

        cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                                  d_model=128, d_ff=256)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        lcfg = LoRAConfig(rank=4, alpha=8, include_mlp=True)
        loras = []
        for i in range(3):
            l = init_lora(jax.random.key(i + 1), params, cfg, lcfg)
            l = jax.tree.map(lambda x, i=i: x + 0.05 * jax.random.normal(
                jax.random.key(50 + i), x.shape), l)
            loras.append(l)
        host = host_apply(params, fedex_residual(loras), lcfg.scale)
        fused = apply_residual_fused(params, loras, lcfg.scale)
        fh = flatten_with_paths(host)
        ff = flatten_with_paths(fused)
        assert set(fh) == set(ff)
        for k in fh:
            np.testing.assert_allclose(np.asarray(ff[k]), np.asarray(fh[k]),
                                       rtol=2e-4, atol=2e-4, err_msg=k)


class TestCommTable:
    def test_table6_orderings(self):
        """full FT ≫ FedEx > FedIT > FFA (paper Table 6)."""
        from repro.core.comm import comm_table
        cfg = get_config("paper-gpt2")
        table = comm_table(cfg, LoRAConfig(rank=4), k=3, rounds=5)
        assert table["full_ft"]["ratio_to_fedex"] > 2.0
        assert table["fedit"]["ratio_to_fedex"] < 1.0
        assert table["ffa"]["ratio_to_fedex"] < table["fedit"]["ratio_to_fedex"]
        assert table["fedex"]["ratio_to_fedex"] == 1.0
