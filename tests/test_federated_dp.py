"""DP-enabled federated runs (FedConfig.dp_clip / dp_noise_multiplier)."""

import numpy as np

from repro.configs import FedConfig, LoRAConfig, TrainConfig
from repro.core import FederatedTrainer
from tests.test_federated import _setup


def _run_dp(noise, rounds=2, steps=6):
    cfg, model, loaders, evals = _setup()
    tr = FederatedTrainer(
        model=model, lora_cfg=LoRAConfig(rank=4, alpha=8, include_mlp=True),
        fed_cfg=FedConfig(num_clients=3, rounds=rounds, local_steps=steps,
                          method="fedex", dp_clip=1.0,
                          dp_noise_multiplier=noise),
        train_cfg=TrainConfig(learning_rate=1e-2, schedule="constant"),
        client_loaders=loaders, eval_batches=evals, seed=0)
    return tr.run()


def test_dp_run_finite():
    hist = _run_dp(noise=0.1)
    assert all(np.isfinite(r.eval_loss) for r in hist)


def test_noise_hurts_monotonically():
    """More DP noise → no better eval loss (sanity, coarse)."""
    low = _run_dp(noise=0.0)[-1].eval_loss
    high = _run_dp(noise=5.0)[-1].eval_loss
    assert high >= low - 0.05
