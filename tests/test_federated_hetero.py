"""Heterogeneous-rank federated training end-to-end (core/hetero.py wired
into FederatedTrainer via FedConfig.client_ranks)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, LoRAConfig, TrainConfig
from repro.core import FederatedTrainer, product_mean
from repro.util.tree import flatten_with_paths
from tests.test_federated import _setup


def _run_hetero(ranks=(2, 4, 8), rounds=2, steps=6):
    cfg, model, loaders, evals = _setup()
    tr = FederatedTrainer(
        model=model, lora_cfg=LoRAConfig(rank=8, alpha=16, include_mlp=True),
        fed_cfg=FedConfig(num_clients=3, rounds=rounds, local_steps=steps,
                          method="fedex", client_ranks=tuple(ranks)),
        train_cfg=TrainConfig(learning_rate=1e-2, schedule="constant"),
        client_loaders=loaders, eval_batches=evals, seed=0)
    return tr, tr.run()


def test_hetero_runs_and_is_finite():
    tr, hist = _run_hetero()
    assert all(np.isfinite(r.eval_loss) for r in hist)


def test_client_ranks_respected_through_rounds():
    tr, hist = _run_hetero(ranks=(2, 4, 8))
    for i, r in enumerate((2, 4, 8)):
        flat = flatten_with_paths(tr._client_lora[i])
        a_paths = [p for p in flat if p.endswith("/a")]
        assert all(flat[p].shape[-1] == r for p in a_paths), f"client {i}"


def test_per_client_effective_weights_agree():
    """After a round, every client's W0ᵢ + scale·aᵢbᵢ must be identical
    (all equal W0_global + scale·mean-of-products) — the exactness invariant
    carried through REAL training with different ranks."""
    tr, hist = _run_hetero(ranks=(2, 4, 8), rounds=1, steps=4)
    effective = []
    for i in range(3):
        from repro.core import merge_lora
        effective.append(flatten_with_paths(
            merge_lora(tr.client_params[i], tr._client_lora[i], tr.scale)))
    for key in effective[0]:
        for i in (1, 2):
            np.testing.assert_allclose(
                np.asarray(effective[0][key], np.float32),
                np.asarray(effective[i][key], np.float32),
                rtol=5e-3, atol=5e-3,
                err_msg=f"{key}: client 0 vs {i} effective weights diverge")
