"""fedsrv coordinator subsystem: sampling determinism, transport codec,
ledger-vs-analytic reconciliation, deadline/quorum semantics, async buffer,
and end-to-end weighted exactness under partial participation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import (FederatedTrainer, apply_residual, init_lora,
                        merge_lora, product_mean)
from repro.core.comm import adapted_matrices, round_comm_params
from repro.data import ClientLoader, SyntheticLM, dirichlet_partition
from repro.fedsrv import (AdapterCodec, AsyncBufferCoordinator, ClientInfo,
                          ClientRegistry, RoundCoordinator, RoundPolicy,
                          SimClock, StragglerModel, weighted_close)
from repro.models import build_model
from repro.util.tree import flatten_with_paths


def make_registry(k=6, seed=0):
    rng = np.random.default_rng(seed)
    return ClientRegistry(
        [ClientInfo(i, num_examples=int(rng.integers(50, 500)))
         for i in range(k)], seed=seed)


def make_loras(k, m=16, r=2, n=12, seed=0):
    rng = np.random.default_rng(seed)
    return {i: {"q_proj": {
        "a": jnp.asarray(rng.normal(size=(m, r)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(r, n)), jnp.float32)}}
        for i in range(k)}


class TestRegistry:
    def test_sampler_deterministic_across_instances(self):
        r1, r2 = make_registry(seed=7), make_registry(seed=7)
        for rnd in range(5):
            ids1 = [c.client_id for c in r1.sample_round(rnd, 0.5)]
            ids2 = [c.client_id for c in r2.sample_round(rnd, 0.5)]
            assert ids1 == ids2

    def test_sampler_fraction_counts(self):
        reg = make_registry(k=10)
        assert len(reg.sample_round(0, 1.0)) == 10
        assert len(reg.sample_round(0, 0.5)) == 5
        assert len(reg.sample_round(0, 0.01, min_clients=2)) == 2

    def test_full_participation_is_id_ordered(self):
        reg = make_registry(k=5)
        assert [c.client_id for c in reg.sample_round(3, 1.0)] == [0, 1, 2, 3, 4]

    def test_sampling_varies_by_round(self):
        reg = make_registry(k=12, seed=1)
        picks = {tuple(c.client_id for c in reg.sample_round(r, 0.25))
                 for r in range(8)}
        assert len(picks) > 1

    def test_weights_sum_to_one(self):
        reg = make_registry()
        w = reg.weights_for([0, 2, 4])
        assert abs(sum(w) - 1.0) < 1e-12
        assert all(x > 0 for x in w)

    def test_duplicate_ids_rejected(self):
        with pytest.raises(ValueError):
            ClientRegistry([ClientInfo(0, 10), ClientInfo(0, 20)])


class TestStragglerModel:
    def test_latency_deterministic(self):
        m1 = StragglerModel(jitter=0.5, straggler_prob=0.3, seed=9)
        m2 = StragglerModel(jitter=0.5, straggler_prob=0.3, seed=9)
        c = ClientInfo(4, 100)
        assert m1.latency(2, c) == m2.latency(2, c)
        assert m1.dropped(2, c) == m2.dropped(2, c)

    def test_compute_speed_scales_latency(self):
        m = StragglerModel(jitter=0.0)
        slow = m.latency(0, ClientInfo(1, 10, compute_speed=0.5))
        fast = m.latency(0, ClientInfo(1, 10, compute_speed=2.0))
        assert slow == pytest.approx(4 * fast)

    def test_dropout_rate(self):
        m = StragglerModel(dropout_prob=0.5, seed=0)
        drops = sum(m.dropped(r, ClientInfo(c, 10))
                    for r in range(20) for c in range(20))
        assert 100 < drops < 300  # ~200 expected


class TestTransportCodec:
    def _tree(self, seed=0):
        rng = np.random.default_rng(seed)
        return {"l": {"q_proj": {
            "a": jnp.asarray(rng.normal(size=(16, 4)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(4, 12)), jnp.float32)}}}

    def test_none_roundtrip_bitwise(self):
        tree = self._tree()
        codec = AdapterCodec("none")
        p = codec.encode(tree, round_id=0, client_id=1)
        out = codec.decode(p)
        for k, v in flatten_with_paths(tree).items():
            np.testing.assert_array_equal(np.asarray(v),
                                          flatten_with_paths(out)[k])
        assert p.num_params == 16 * 4 + 4 * 12
        assert p.nbytes == 4 * p.num_params

    def test_fp16_roundtrip(self):
        tree = self._tree()
        codec = AdapterCodec("fp16")
        p = codec.encode(tree, round_id=0, client_id=1)
        assert p.nbytes == 2 * p.num_params
        out = codec.decode(p)
        for k, v in flatten_with_paths(tree).items():
            np.testing.assert_allclose(np.asarray(v),
                                       flatten_with_paths(out)[k],
                                       rtol=1e-3, atol=1e-3)

    def test_int8_roundtrip_bounded_error(self):
        tree = self._tree()
        codec = AdapterCodec("int8")
        p = codec.encode(tree, round_id=0, client_id=1)
        assert p.nbytes == p.num_params + 4 * len(p.tensors)
        out = codec.decode(p)
        for k, v in flatten_with_paths(tree).items():
            arr = np.asarray(v)
            scale = np.abs(arr).max() / 127.0
            np.testing.assert_allclose(arr, flatten_with_paths(out)[k],
                                       atol=scale / 2 + 1e-7)

    def test_downlink_never_quantized(self):
        codec = AdapterCodec("int8")
        p = codec.encode(self._tree(), round_id=0, client_id=-1,
                         direction="downlink")
        assert p.codec == "none"


class TestLedgerReconciliation:
    """Satellite: measured transport ledger == analytic core/comm.py counts
    at partial participation, on the REAL tiny model's adapter tree."""

    @pytest.mark.parametrize("fraction", [0.5, 1.0])
    def test_uplink_matches_round_comm_params(self, fraction):
        cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                                  vocab_size=64)
        model = build_model(cfg)
        lcfg = LoRAConfig(rank=4)
        params = model.init(jax.random.key(0))
        lora = init_lora(jax.random.key(1), params, cfg, lcfg)

        k = 4
        reg = ClientRegistry([ClientInfo(i, 100 + i) for i in range(k)])
        coord = RoundCoordinator(reg, RoundPolicy(participation=fraction))
        coord.run_round(0, lambda c, g, r: g, global_lora=lora)

        mats = adapted_matrices(cfg, lcfg)
        analytic = round_comm_params("fedex", mats, lcfg.rank, k,
                                     participation_fraction=fraction)
        rec = coord.ledger.reconcile(0, analytic)
        assert rec["uplink"]["match"], rec

    def test_min_clients_floor_matches_sampler(self):
        """When the quorum floor exceeds ⌈f·k⌉ the analytic count follows the
        sampler (which samples max(min_quorum, ⌈f·k⌉) clients)."""
        from repro.core.comm import participating_clients
        assert participating_clients(20, 0.1) == 2
        assert participating_clients(20, 0.1, min_clients=5) == 5
        assert participating_clients(20, 1.0, min_clients=5) == 20

    def test_participation_reduces_comm(self):
        cfg = get_config("paper-tiny")
        mats = adapted_matrices(cfg, LoRAConfig(rank=4))
        full = round_comm_params("fedex", mats, 4, 20)
        tenth = round_comm_params("fedex", mats, 4, 20,
                                  participation_fraction=0.1)
        assert tenth["uplink"] == full["uplink"] // 10
        assert tenth["total"] < full["total"]
        # default fraction reproduces the historical numbers
        assert round_comm_params("fedex", mats, 4, 3) == round_comm_params(
            "fedex", mats, 4, 3, participation_fraction=1.0)


class TestRoundCoordinator:
    def test_trivial_policy_delivers_all_in_order(self):
        k = 5
        reg = make_registry(k=k)
        coord = RoundCoordinator(reg)
        loras = make_loras(k)
        out = coord.run_round(0, lambda c, g, r: loras[c.client_id],
                              global_lora=loras[0])
        assert out.client_ids == list(range(k))
        assert out.weights is None  # uniform → legacy bitwise path
        assert out.dropped_out == [] and out.dropped_deadline == []

    def test_deadline_drops_stragglers_after_quorum(self):
        k = 4
        reg = ClientRegistry([ClientInfo(i, 100) for i in range(k)])
        # deterministic latencies 1.0 (jitter=0, no stragglers): set a
        # deadline below 1.0 with quorum 2 → first two arrivals are accepted
        # (quorum must be met even past the deadline), the rest are dropped.
        coord = RoundCoordinator(
            reg, RoundPolicy(deadline=0.5, min_quorum=2),
            StragglerModel(jitter=0.0))
        loras = make_loras(k)
        out = coord.run_round(0, lambda c, g, r: loras[c.client_id],
                              global_lora=loras[0])
        assert len(out.delivered) == 2
        assert len(out.dropped_deadline) == 2

    def test_deadline_alone_drops_without_explicit_quorum(self):
        """min_quorum=0 must not neuter the deadline: any single delivery
        lets late arrivals be cut."""
        k = 3
        reg = ClientRegistry([ClientInfo(i, 100) for i in range(k)])
        coord = RoundCoordinator(
            reg, RoundPolicy(deadline=0.5), StragglerModel(jitter=0.0))
        loras = make_loras(k)
        out = coord.run_round(0, lambda c, g, r: loras[c.client_id],
                              global_lora=loras[0])
        assert len(out.delivered) == 1
        assert len(out.dropped_deadline) == 2

    def test_deadline_keeps_all_on_time_arrivals(self):
        k = 4
        reg = ClientRegistry([ClientInfo(i, 100) for i in range(k)])
        coord = RoundCoordinator(
            reg, RoundPolicy(deadline=10.0, min_quorum=2),
            StragglerModel(jitter=0.0))
        loras = make_loras(k)
        out = coord.run_round(0, lambda c, g, r: loras[c.client_id],
                              global_lora=loras[0])
        assert len(out.delivered) == k

    def test_dropout_excluded(self):
        k = 6
        reg = ClientRegistry([ClientInfo(i, 100) for i in range(k)], seed=0)
        coord = RoundCoordinator(
            reg, RoundPolicy(), StragglerModel(dropout_prob=0.5, seed=5))
        loras = make_loras(k)
        out = coord.run_round(0, lambda c, g, r: loras[c.client_id],
                              global_lora=loras[0])
        assert set(out.client_ids) | set(out.dropped_out) == set(range(k))
        assert 0 < len(out.dropped_out) < k

    def test_clock_advances_monotonically(self):
        reg = make_registry(k=3)
        clock = SimClock()
        coord = RoundCoordinator(reg, clock=clock)
        loras = make_loras(3)
        t_seen = []
        for rnd in range(3):
            out = coord.run_round(rnd, lambda c, g, r: loras[c.client_id],
                                  global_lora=loras[0])
            t_seen.append(out.closed_at)
        assert t_seen == sorted(t_seen)
        assert t_seen[0] > 0

    def test_weighted_close_exact_on_delivered_subset(self):
        k = 8
        reg = make_registry(k=k, seed=3)
        coord = RoundCoordinator(
            reg, RoundPolicy(participation=0.5, weighting="examples"),
            StragglerModel(straggler_prob=0.25, seed=4))
        loras = make_loras(k, seed=5)
        out = coord.run_round(0, lambda c, g, r: loras[c.client_id],
                              global_lora=loras[0])
        g, res = weighted_close(out, "fedex")
        ideal = product_mean([d.lora for d in out.delivered], out.weights)
        got = jnp.matmul(g["q_proj"]["a"], g["q_proj"]["b"]) + res["q_proj"]
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ideal["q_proj"]),
                                   rtol=1e-5, atol=1e-6)


class TestAsyncBuffer:
    def test_staleness_appears_and_commit_is_exact(self):
        k = 3
        reg = ClientRegistry([ClientInfo(i, 100 * (i + 1)) for i in range(k)],
                             seed=0)
        coord = AsyncBufferCoordinator(
            reg, RoundPolicy(weighting="examples"),
            StragglerModel(jitter=0.6, seed=1), buffer_size=1)
        loras = make_loras(k, seed=2)
        stalenesses = []
        for rnd in range(4):
            out = coord.run_round(rnd, lambda c, g, r: loras[c.client_id],
                                  global_lora=loras[0])
            stalenesses += [d.staleness for d in out.delivered]
            # weights always normalized, commit identity exact
            assert abs(sum(out.weights) - 1.0) < 1e-12
            g, res = weighted_close(out, "fedex")
            ideal = product_mean([d.lora for d in out.delivered], out.weights)
            got = (jnp.matmul(g["q_proj"]["a"], g["q_proj"]["b"])
                   + res["q_proj"])
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(ideal["q_proj"]),
                                       rtol=1e-5, atol=1e-6)
        # buffer_size=1 with 3 clients in flight → later commits pop launches
        # from older versions
        assert max(stalenesses) > 0

    def test_empty_commit_is_graceful(self):
        """All sampled clients dropping out must yield an empty commit, not
        a crash (mirrors the sync coordinator's zero-delivery round)."""
        reg = ClientRegistry([ClientInfo(0, 100), ClientInfo(1, 100)])
        coord = AsyncBufferCoordinator(
            reg, RoundPolicy(), StragglerModel(dropout_prob=1.0),
            buffer_size=2)
        loras = make_loras(2)
        out = coord.run_round(0, lambda c, g, r: loras[c.client_id],
                              global_lora=loras[0])
        assert out.delivered == [] and out.weights is None
        assert sorted(out.dropped_out) == [0, 1]

    def test_staleness_discounts_weight(self):
        # two clients, equal examples: the stale one must weigh less
        reg = ClientRegistry([ClientInfo(0, 100), ClientInfo(1, 100)], seed=0)
        coord = AsyncBufferCoordinator(
            reg, RoundPolicy(weighting="examples"),
            StragglerModel(jitter=0.8, seed=3), buffer_size=1,
            staleness_alpha=1.0)
        loras = make_loras(2)
        for rnd in range(3):
            out = coord.run_round(rnd, lambda c, g, r: loras[c.client_id],
                                  global_lora=loras[0])
            d = out.delivered[0]
            expected = 1.0  # single-delivery commit renormalizes to 1
            assert out.weights[0] == pytest.approx(expected)
            assert d.staleness >= 0


class TestTrainerIntegration:
    """End-to-end acceptance: a real fedsrv round with sampled clients and
    non-uniform example counts is exact after residual fold-in."""

    def _setup(self, fed_cfg, vocab=16, clients=4, seed=0):
        cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                                  vocab_size=vocab)
        model = build_model(cfg)
        ds = SyntheticLM(vocab=vocab, num_tasks=clients, seed=seed)
        seqs, labels = [], []
        for t in range(clients):
            n = 30 + 20 * t  # unequal shards → non-uniform example weights
            seqs.append(ds.sample(task=t, num_sequences=n, seq_len=32,
                                  seed=seed + t))
            labels += [t] * n
        seqs = np.concatenate(seqs)
        parts = dirichlet_partition(np.array(labels), clients, alpha=0.5,
                                    seed=seed)
        loaders = [ClientLoader(seqs[p], batch_size=8, seed=seed + i)
                   for i, p in enumerate(parts)]
        trainer = FederatedTrainer(
            model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
            fed_cfg=fed_cfg,
            train_cfg=TrainConfig(learning_rate=1e-2, schedule="constant"),
            client_loaders=loaders, eval_batches=[], seed=seed)
        return trainer

    def _assert_round_exact(self, trainer):
        params0 = trainer.params
        trainer.run()
        out = trainer.outcomes[0]
        assert out.delivered, "round delivered nothing"
        scale = trainer.scale
        w_fedex = merge_lora(trainer.params, trainer.global_lora, scale)
        ideal = product_mean([d.lora for d in out.delivered], out.weights)
        w_ideal = apply_residual(params0, ideal, scale)
        fa, fb = flatten_with_paths(w_fedex), flatten_with_paths(w_ideal)
        assert set(fa) == set(fb)
        for k in fa:
            np.testing.assert_allclose(np.asarray(fa[k]), np.asarray(fb[k]),
                                       rtol=1e-5, atol=1e-6, err_msg=k)

    def test_partial_participation_round_exact(self):
        trainer = self._setup(FedConfig(
            num_clients=4, rounds=1, local_steps=3, method="fedex",
            participation=0.5, weighting="examples"))
        self._assert_round_exact(trainer)
        out = trainer.outcomes[0]
        assert len(out.delivered) == 2  # ⌈0.5·4⌉ sampled, none dropped
        assert out.weights is not None and len(set(out.weights)) > 1

    def test_straggler_deadline_round_exact(self):
        trainer = self._setup(FedConfig(
            num_clients=4, rounds=1, local_steps=3, method="fedex",
            weighting="examples", straggler_prob=0.5, straggler_factor=10.0,
            round_deadline=2.0, min_quorum=2))
        self._assert_round_exact(trainer)

    def test_async_buffer_commit_exact(self):
        trainer = self._setup(FedConfig(
            num_clients=4, rounds=1, local_steps=3, method="fedex",
            weighting="examples", async_buffer=2, latency_jitter=0.5))
        self._assert_round_exact(trainer)
        assert len(trainer.outcomes[0].delivered) == 2  # buffer size

    def test_quantized_uplink_aggregates_transmitted_values(self):
        """With int8 uplink the server aggregates the DEQUANTIZED adapters —
        exactness holds wrt what was transmitted (outcome.delivered)."""
        trainer = self._setup(FedConfig(
            num_clients=3, rounds=1, local_steps=2, method="fedex",
            weighting="examples", quantize_uplink="int8"))
        self._assert_round_exact(trainer)

    def test_trainer_ledger_populated(self):
        trainer = self._setup(FedConfig(
            num_clients=3, rounds=2, local_steps=2, method="fedex",
            participation=1.0))
        trainer.run()
        totals = trainer.ledger.totals()
        assert totals["uplink_params"] > 0
        assert totals["downlink_params"] > totals["uplink_params"]  # +residual
