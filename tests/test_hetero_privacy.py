"""Beyond-paper extensions: heterogeneous-rank exact aggregation + DP uploads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.hetero import hetero_fedex_aggregate
from repro.core.privacy import clip_delta, l2_norm, privatize_upload
from repro.core import fedex_aggregate, product_mean


def _mk_hetero(ranks, m=20, n=14, seed=0, layers=None):
    rng = np.random.default_rng(seed)
    lead = () if layers is None else (layers,)
    out = []
    for r in ranks:
        out.append({"w": {
            "a": jnp.asarray(rng.normal(size=lead + (m, r)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=lead + (r, n)), jnp.float32),
        }})
    return out


class TestHeteroRank:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           ranks=st.lists(st.integers(1, 5), min_size=2, max_size=4))
    def test_every_client_exact(self, seed, ranks):
        """W0 + residᵢ + aᵢ'bᵢ' == W0 + mean(aⱼbⱼ) for EVERY client rank."""
        loras = _mk_hetero(ranks, seed=seed)
        ideal = product_mean(loras)["w"]
        new_loras, residuals = hetero_fedex_aggregate(loras, ranks)
        for i in range(len(ranks)):
            got = (jnp.matmul(new_loras[i]["w"]["a"], new_loras[i]["w"]["b"])
                   + residuals[i]["w"])
            np.testing.assert_allclose(np.asarray(got), np.asarray(ideal),
                                       rtol=2e-4, atol=2e-4)

    def test_client_rank_respected(self):
        loras = _mk_hetero([2, 4, 3])
        new_loras, _ = hetero_fedex_aggregate(loras, [2, 4, 3])
        assert new_loras[0]["w"]["a"].shape[-1] == 2
        assert new_loras[1]["w"]["a"].shape[-1] == 4
        assert new_loras[2]["w"]["b"].shape[-2] == 3

    def test_truncation_is_optimal_per_client(self):
        """Each client's adapters are the best rank-rᵢ approx of the ideal."""
        loras = _mk_hetero([2, 6], seed=3)
        ideal = np.asarray(product_mean(loras)["w"])
        new_loras, _ = hetero_fedex_aggregate(loras, [2, 6])
        u, s, vt = np.linalg.svd(ideal, full_matrices=False)
        for i, r in enumerate([2, 6]):
            best = (u[:, :r] * s[:r]) @ vt[:r]
            got = np.asarray(jnp.matmul(new_loras[i]["w"]["a"],
                                        new_loras[i]["w"]["b"]))
            np.testing.assert_allclose(np.linalg.norm(ideal - got),
                                       np.linalg.norm(ideal - best), rtol=1e-4)

    def test_stacked_layers(self):
        loras = _mk_hetero([2, 3], layers=4, seed=5)
        ideal = product_mean(loras)["w"]
        new_loras, residuals = hetero_fedex_aggregate(loras, [2, 3])
        assert new_loras[0]["w"]["a"].shape == (4, 20, 2)
        got = (jnp.matmul(new_loras[1]["w"]["a"], new_loras[1]["w"]["b"])
               + residuals[1]["w"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ideal),
                                   rtol=2e-4, atol=2e-4)

    def test_uniform_rank_matches_keep_capacity(self):
        """With equal ranks ≥ true rank, clients recover the ideal exactly
        (residual ≈ 0)."""
        loras = _mk_hetero([3, 3], m=10, n=8, seed=7)
        # rank(ideal) ≤ 6; give clients rank 8 ≥ 6 via padding ranks
        loras_big = _mk_hetero([8, 8], m=10, n=8, seed=7)
        new_loras, residuals = hetero_fedex_aggregate(loras_big, [8, 8])
        assert float(jnp.abs(residuals[0]["w"]).max()) < 1e-4


class TestPrivacy:
    def test_clip_bounds_norm(self):
        delta = {"a": jnp.ones((10,)) * 5.0}
        clipped, norm = clip_delta(delta, 1.0)
        assert float(l2_norm(clipped)) <= 1.0 + 1e-5
        np.testing.assert_allclose(float(norm), np.sqrt(250.0), rtol=1e-6)

    def test_no_noise_no_clip_is_identity(self):
        rng = np.random.default_rng(0)
        g = {"a": jnp.asarray(rng.normal(size=(6, 2)), jnp.float32)}
        l = {"a": g["a"] + 0.01}
        out = privatize_upload(jax.random.key(0), l, g, clip=1e9,
                               noise_multiplier=0.0)
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(l["a"]),
                                   rtol=1e-6)

    def test_fedex_exact_wrt_noised_adapters(self):
        """The paper's prediction: DP noise does NOT break exactness — the
        residual absorbs whatever the clients uploaded."""
        rng = np.random.default_rng(1)
        g = {"w": {"a": jnp.asarray(rng.normal(size=(12, 3)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(3, 9)), jnp.float32)}}
        uploads = []
        for i in range(3):
            local = jax.tree.map(
                lambda x, i=i: x + 0.1 * jax.random.normal(
                    jax.random.key(10 + i), x.shape), g)
            uploads.append(privatize_upload(jax.random.key(i), local, g,
                                            clip=0.5, noise_multiplier=0.3))
        glob, res = fedex_aggregate(uploads)
        ideal = product_mean(uploads)["w"]
        got = jnp.matmul(glob["w"]["a"], glob["w"]["b"]) + res["w"]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ideal),
                                   rtol=2e-4, atol=2e-4)

    def test_noise_increases_divergence(self):
        from repro.core import mean_deviation
        rng = np.random.default_rng(2)
        g = {"w": {"a": jnp.asarray(rng.normal(size=(12, 3)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(3, 9)), jnp.float32)}}
        locals_ = [jax.tree.map(
            lambda x, i=i: x + 0.05 * jax.random.normal(
                jax.random.key(20 + i), x.shape), g) for i in range(3)]
        clean_div = mean_deviation(locals_)
        noised = [privatize_upload(jax.random.key(i), l, g, clip=10.0,
                                   noise_multiplier=1.0)
                  for i, l in enumerate(locals_)]
        noisy_div = mean_deviation(noised)
        assert noisy_div > clean_div
