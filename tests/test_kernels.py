"""Per-kernel allclose sweeps against the ref.py oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fedex_fold, lora_dense, swa_attention
from repro.kernels import ref
from repro.kernels.fedex_residual import fedex_residual_apply
from repro.kernels.flash_swa import flash_swa
from repro.kernels.lora_matmul import lora_matmul


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


class TestLoraMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                       (128, 256, 512)])
    @pytest.mark.parametrize("r", [1, 4, 16])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, m, k, n, r, dtype):
        rng = np.random.default_rng(hash((m, k, n, r, str(dtype))) % 2**31)
        x = _rand(rng, (m, k), dtype)
        w = _rand(rng, (k, n), dtype)
        a = _rand(rng, (k, r), dtype)
        b = _rand(rng, (r, n), dtype)
        y = lora_matmul(x, w, a, b, scale=0.7, interpret=True)
        yr = ref.lora_matmul_ref(x, w, a, b, 0.7)
        tol = 2e-5 if dtype == jnp.float32 else 4e-2
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=tol, atol=tol * np.abs(np.asarray(yr)).max())

    def test_scale_zero_is_base_matmul(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, (128, 128), jnp.float32)
        w = _rand(rng, (128, 128), jnp.float32)
        a = _rand(rng, (128, 4), jnp.float32)
        b = _rand(rng, (4, 128), jnp.float32)
        y = lora_matmul(x, w, a, b, scale=0.0, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-3)

    def test_wrapper_handles_leading_dims(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, (2, 4, 128), jnp.float32)
        w = _rand(rng, (128, 256), jnp.float32)
        a = _rand(rng, (128, 8), jnp.float32)
        b = _rand(rng, (8, 256), jnp.float32)
        y = lora_dense(x, w, a, b, 0.5)
        yr = ref.lora_matmul_ref(x.reshape(-1, 128), w, a, b, 0.5).reshape(2, 4, 256)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-3)


class TestFedexResidual:
    @pytest.mark.parametrize("c", [1, 3, 8])
    @pytest.mark.parametrize("m,n", [(256, 256), (512, 256), (256, 1024)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, c, m, n, dtype):
        rng = np.random.default_rng(hash((c, m, n, str(dtype))) % 2**31)
        r = 4
        w0 = _rand(rng, (m, n), dtype)
        a = _rand(rng, (c, m, r), dtype)
        b = _rand(rng, (c, r, n), dtype)
        out = fedex_residual_apply(w0, a, b, scale=2.0, interpret=True)
        outr = ref.fedex_residual_ref(w0, a, b, 2.0)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                                   rtol=tol, atol=tol * max(1.0, np.abs(np.asarray(outr)).max()))

    def test_matches_aggregation_module(self):
        """Kernel result == core.aggregation residual + fold (the jnp path)."""
        from repro.core import apply_residual, fedex_aggregate
        rng = np.random.default_rng(7)
        m, r, n, c = 256, 4, 256, 3
        w0 = _rand(rng, (m, n), jnp.float32)
        loras = [{"w": {"a": _rand(rng, (m, r), jnp.float32),
                        "b": _rand(rng, (r, n), jnp.float32)}} for _ in range(c)]
        _, res = fedex_aggregate(loras)
        host = apply_residual({"w": {"kernel": w0}}, res, 1.5)["w"]["kernel"]
        a = jnp.stack([l["w"]["a"] for l in loras])
        b = jnp.stack([l["w"]["b"] for l in loras])
        kern = fedex_fold(w0, a, b, 1.5)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(host),
                                   rtol=1e-4, atol=1e-4)


class TestFlashSWA:
    @pytest.mark.parametrize("s", [128, 256, 512])
    @pytest.mark.parametrize("window", [0, 64, 200])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, s, window, dtype):
        rng = np.random.default_rng(hash((s, window, str(dtype))) % 2**31)
        bh, d = 4, 64
        q = _rand(rng, (bh, s, d), dtype)
        k = _rand(rng, (bh, s, d), dtype)
        v = _rand(rng, (bh, s, d), dtype)
        out = flash_swa(q, k, v, causal=True, window=window, bq=128, bk=128,
                        interpret=True)
        outr = ref.flash_swa_ref(q, k, v, causal=True, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(outr),
                                   rtol=tol, atol=tol * 2)

    def test_non_causal(self):
        rng = np.random.default_rng(3)
        q = _rand(rng, (2, 128, 64), jnp.float32)
        k = _rand(rng, (2, 128, 64), jnp.float32)
        v = _rand(rng, (2, 128, 64), jnp.float32)
        out = flash_swa(q, k, v, causal=False, interpret=True)
        outr = ref.flash_swa_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                                   rtol=1e-4, atol=1e-4)

    def test_gqa_wrapper(self):
        rng = np.random.default_rng(4)
        b, s, h, kv, d = 2, 256, 8, 2, 64
        q = _rand(rng, (b, s, h, d), jnp.float32)
        k = _rand(rng, (b, s, kv, d), jnp.float32)
        v = _rand(rng, (b, s, kv, d), jnp.float32)
        out = swa_attention(q, k, v, causal=True, window=100)
        from repro.models.attention import blockwise_attention
        bw = blockwise_attention(q, k, v, causal=True, window=100, block_size=128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(bw),
                                   rtol=2e-4, atol=2e-4)
