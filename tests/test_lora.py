"""LoRA adapter-tree construction, target resolution, merge semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LoRAConfig, get_config
from repro.core import init_lora, lora_param_count, merge_lora, resolve_targets
from repro.data import make_batch_for
from repro.models import build_model
from repro.util.tree import flatten_with_paths


def _f32(name):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


class TestInit:
    def test_structure_mirrors_params(self):
        cfg = _f32("granite-8b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        lora = init_lora(jax.random.key(1), params, cfg, LoRAConfig(rank=4))
        flat = flatten_with_paths(lora)
        # every adapter leaf path must exist in params with matching lead dims
        pflat = flatten_with_paths(params)
        for path in flat:
            base = path.rsplit("/", 1)[0]  # strip /a or /b
            assert base + "/kernel" in pflat, path
        # stacked layers: factors carry the layer axis
        a = lora["layers"]["attn"]["q_proj"]["a"]
        assert a.shape[0] == cfg.num_layers
        assert a.shape[-1] == 4

    def test_b_initialized_zero(self):
        cfg = _f32("qwen2.5-3b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        lora = init_lora(jax.random.key(1), params, cfg, LoRAConfig(rank=2))
        for path, leaf in flatten_with_paths(lora).items():
            if path.endswith("/b"):
                np.testing.assert_allclose(np.asarray(leaf), 0.0)

    def test_include_mlp_adds_targets(self):
        cfg = _f32("granite-8b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        small = init_lora(jax.random.key(1), params, cfg, LoRAConfig(rank=2))
        big = init_lora(jax.random.key(1), params, cfg,
                        LoRAConfig(rank=2, include_mlp=True))
        assert lora_param_count(big) > lora_param_count(small)
        assert "mlp" in big["layers"]

    @pytest.mark.parametrize("name", ["zamba2-7b", "xlstm-1.3b", "deepseek-v2-236b",
                                      "whisper-medium", "mixtral-8x22b"])
    def test_family_targets_nonempty(self, name):
        cfg = _f32(name)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        lora = init_lora(jax.random.key(1), params, cfg, LoRAConfig(rank=2))
        assert lora_param_count(lora) > 0
        assert len(resolve_targets(cfg, LoRAConfig())) > 0

    def test_expert_lora_flag(self):
        cfg = _f32("mixtral-8x22b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        lora = init_lora(jax.random.key(1), params, cfg,
                         LoRAConfig(rank=2, lora_experts=True, include_mlp=True))
        flat = flatten_with_paths(lora)
        expert_paths = [p for p in flat if "/experts/" in p]
        assert expert_paths, "per-expert adapters missing"
        # per-expert factors carry (L, E, …)
        a = flat[[p for p in expert_paths if p.endswith("up_proj/a")][0]]
        assert a.shape[1] == cfg.num_experts


class TestMergeAndForwardEquivalence:
    @pytest.mark.parametrize("name", ["qwen2.5-3b", "granite-8b"])
    def test_adapter_apply_equals_merged(self, name):
        """forward(W0, lora) == forward(W0 + scale·ab, no lora)."""
        cfg = _f32(name)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        lcfg = LoRAConfig(rank=4, alpha=8)
        lora = init_lora(jax.random.key(1), params, cfg, lcfg)
        # give b nonzero values so the adapter does something
        lora = jax.tree.map(
            lambda x: x + 0.02 * jax.random.normal(jax.random.key(7), x.shape), lora)
        batch = make_batch_for(cfg, 2, 16, seed=0)
        logits_adapter, _ = model.apply(params, batch, lora=lora,
                                        lora_scale=lcfg.scale)
        merged = merge_lora(params, lora, lcfg.scale)
        logits_merged, _ = model.apply(merged, batch)
        np.testing.assert_allclose(np.asarray(logits_adapter),
                                   np.asarray(logits_merged), rtol=2e-3, atol=2e-3)

    def test_zero_b_is_noop(self):
        cfg = _f32("qwen2.5-3b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        lora = init_lora(jax.random.key(1), params, cfg, LoRAConfig(rank=4))
        batch = make_batch_for(cfg, 2, 16, seed=0)
        with_lora, _ = model.apply(params, batch, lora=lora, lora_scale=2.0)
        without, _ = model.apply(params, batch)
        np.testing.assert_allclose(np.asarray(with_lora), np.asarray(without),
                                   rtol=1e-5, atol=1e-5)
