"""Mesh-mode partial-participation rounds (launch/mesh_train.py).

Acceptance contracts under test (ISSUE 5 tentpole):

* a 50 %-sampled, non-uniformly-weighted round closes inside ONE pjit'd
  program — asserted via the close's compile-cache count staying at 1 across
  rounds with different subsets/weights AND via jaxpr inspection (no host
  callbacks inside the close program);
* the mesh close matches the eager weighted oracle
  (``fedex_aggregate`` + ``apply_residual`` over the sampled subset) to the
  documented ≤ ~1e-5 float32 tolerance;
* the divergence leaves the close as an UNRESOLVED DeferredDivergence device
  handle (no host sync inside the close) and resolves to the same value as
  the eager ``mean_deviation`` over the subset;
* the end-to-end MeshFederatedTrainer runs partial-participation rounds on a
  real (tiny) model, resolves every handle by the time ``run()`` returns,
  and still reports exactly one compiled close program.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FedConfig, LoRAConfig, TrainConfig, get_config
from repro.core import aggregation as agg
from repro.core.divergence import mean_deviation
from repro.core.engine import DeferredDivergence
from repro.launch.mesh import make_client_mesh
from repro.launch.mesh_train import (MeshFederatedTrainer, MeshRoundCloser,
                                     make_mesh_round_fn)
from repro.util.tree import flatten_with_paths


def _mk(rng, sh):
    return jnp.asarray(rng.normal(size=sh), jnp.float32)


def _setting(c=4, m=24, n=20, r=3, layers=0, seed=0):
    """Synthetic params + per-client adapter trees (like test_engine)."""
    rng = np.random.default_rng(seed)
    lead = (layers,) if layers else ()
    params = {"blk": {"q_proj": {"kernel": _mk(rng, lead + (m, n))},
                      "o_proj": {"kernel": _mk(rng, lead + (m, n))}}}
    loras = [
        {"blk": {p: {"a": _mk(rng, lead + (m, r)), "b": _mk(rng, lead + (r, n))}
                 for p in ("q_proj", "o_proj")}}
        for _ in range(c)
    ]
    return params, loras


def _stacks(loras):
    flats = [flatten_with_paths(l) for l in loras]
    return {p: jnp.stack([f[p] for f in flats]) for p in flats[0]}


def _closer(params, loras, scale=2.0, **kw):
    mesh = make_client_mesh(len(loras))
    return MeshRoundCloser(mesh, params, loras[0], c_max=len(loras),
                           scale=scale, **kw)


def _eager_close(params, loras, ids, weights, scale=2.0):
    subset = [loras[i] for i in ids]
    g, res = agg.fedex_aggregate(subset, weights)
    return g, agg.apply_residual(params, res, scale)


def _assert_close(a, b, tol=1e-5, msg=""):
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_allclose(np.asarray(fa[k], np.float32),
                                   np.asarray(fb[k], np.float32),
                                   rtol=tol, atol=tol, err_msg=f"{msg} at {k}")


class TestMeshCloser:
    def test_partial_weighted_matches_eager_oracle(self):
        """50 % sampling + non-uniform weights ≡ the eager weighted close."""
        params, loras = _setting(c=4)
        closer = _closer(params, loras)
        ids, weights = [0, 2], [0.3, 0.7]
        g_o, p_o = _eager_close(params, loras, ids, weights)
        g_m, p_m, _ = closer.close(params, _stacks(loras), ids, weights)
        _assert_close(g_m, g_o, msg="global factors")
        _assert_close(p_m, p_o, msg="folded params")

    def test_full_uniform_matches_eager_oracle(self):
        params, loras = _setting(c=4)
        closer = _closer(params, loras)
        ids = list(range(4))
        g_o, p_o = _eager_close(params, loras, ids, None)
        g_m, p_m, _ = closer.close(params, _stacks(loras), ids)
        _assert_close(g_m, g_o, msg="global factors")
        _assert_close(p_m, p_o, msg="folded params")

    def test_stacked_layer_leaves(self):
        params, loras = _setting(c=3, layers=2)
        closer = _closer(params, loras)
        ids, weights = [0, 1], [0.6, 0.4]
        g_o, p_o = _eager_close(params, loras, ids, weights)
        g_m, p_m, _ = closer.close(params, _stacks(loras), ids, weights)
        _assert_close(g_m, g_o)
        _assert_close(p_m, p_o)

    def test_one_compiled_program_across_rounds(self):
        """Sampling patterns and weights change the weight VECTOR, never the
        program: full, 50 %-sampled and example-weighted rounds all reuse one
        compiled close (the C_max padding contract on the mesh)."""
        params, loras = _setting(c=4)
        closer = _closer(params, loras)
        rounds = [
            (list(range(4)), None),            # full uniform
            ([0, 2], [0.3, 0.7]),              # 50 % sampled, weighted
            ([1, 2, 3], [5.0, 1.0, 2.0]),      # ragged quorum, weighted
            ([0, 1], None),                    # 50 % sampled, uniform
        ]
        for ids, weights in rounds:
            g, p, div = closer.close(params, _stacks(loras), ids, weights)
            assert closer.compiled_programs == 1, (
                f"round over {ids} recompiled the close "
                f"({closer.compiled_programs} programs)")

    def test_close_jaxpr_has_no_host_callbacks(self):
        """Jaxpr inspection: the whole close — weighted means, residual fold,
        divergence — is one program with NO host callback/transfer primitive
        inside it (the deferred-divergence contract at the program level)."""
        params, loras = _setting(c=4)
        closer = _closer(params, loras)
        stacks = _stacks(loras)
        w, mask = closer.weight_vector([0, 2], [0.3, 0.7])
        from repro.core.engine import collect_w0_leaves
        w0 = collect_w0_leaves(closer.specs, params)
        jaxpr = jax.make_jaxpr(
            lambda *a: closer._close(*a, uniform=False))(
                w0, stacks, jnp.asarray(w), jnp.asarray(mask))

        def walk(jx):
            for eqn in jx.eqns:
                assert "callback" not in eqn.primitive.name, eqn.primitive
                assert eqn.primitive.name not in ("infeed", "outfeed"), (
                    eqn.primitive)
                for v in eqn.params.values():
                    if hasattr(v, "jaxpr"):
                        walk(v.jaxpr)
        walk(jaxpr.jaxpr)

    def test_divergence_deferred_then_matches_mean_deviation(self):
        params, loras = _setting(c=4)
        closer = _closer(params, loras)
        ids = [0, 2]
        # no host sync inside the close: the handle comes back unresolved
        # (transfer_guard enforces it on accelerators; structural on CPU)
        with jax.transfer_guard_device_to_host("disallow"):
            _, _, div = closer.close(params, _stacks(loras), ids)
        assert isinstance(div, DeferredDivergence)
        assert not div.resolved
        assert isinstance(div.raw, jax.Array)
        expect = float(mean_deviation([loras[i] for i in ids]))
        np.testing.assert_allclose(div.resolve(), expect, rtol=1e-4)
        assert div.resolved and div.raw is None
        # resolution is cached, further numeric uses are free
        assert float(div) == div.resolve()

    def test_mask_zeroes_unsampled_lanes(self):
        """Garbage in a zero-weight lane never reaches the close output."""
        params, loras = _setting(c=4)
        closer = _closer(params, loras)
        ids, weights = [1, 3], [0.5, 0.5]
        stacks = _stacks(loras)
        poisoned = {p: x.at[0].set(1e6) for p, x in stacks.items()}
        g_ref, p_ref, _ = closer.close(params, stacks, ids, weights)
        g_poi, p_poi, _ = closer.close(params, poisoned, ids, weights)
        _assert_close(g_poi, g_ref)
        _assert_close(p_poi, p_ref)

    def test_rejects_unsupported_method_and_bad_ids(self):
        params, loras = _setting(c=3)
        with pytest.raises(ValueError, match="mesh mode closes"):
            _closer(params, loras, method="keep_local")
        closer = _closer(params, loras)
        with pytest.raises(ValueError, match="no participants"):
            closer.close(params, _stacks(loras), [])
        with pytest.raises(ValueError, match="outside"):
            closer.close(params, _stacks(loras), [5])
        with pytest.raises(ValueError, match="duplicate"):
            closer.close(params, _stacks(loras), [1, 1])

    def test_weights_follow_caller_order_not_sorted_ids(self):
        """weights[i] belongs to client_ids[i] however the subset is listed:
        an unsorted subset must not silently swap client weights."""
        params, loras = _setting(c=4)
        closer = _closer(params, loras)
        w_unsorted, _ = closer.weight_vector([2, 0], [0.7, 0.3])
        w_sorted, _ = closer.weight_vector([0, 2], [0.3, 0.7])
        np.testing.assert_allclose(w_unsorted, w_sorted)
        assert w_unsorted[2] == pytest.approx(0.7)
        g_a, p_a, _ = closer.close(params, _stacks(loras), [2, 0], [0.7, 0.3])
        g_b, p_b, _ = closer.close(params, _stacks(loras), [0, 2], [0.3, 0.7])
        _assert_close(g_a, g_b)
        _assert_close(p_a, p_b)


def _mesh_trainer(participation=0.5, weighting="examples", clients=4,
                  rounds=2, local_steps=2, vocab=16, seq=16):
    cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                              vocab_size=vocab)
    from repro.data import ClientLoader, SyntheticLM
    from repro.models import build_model

    model = build_model(cfg)
    ds = SyntheticLM(vocab=vocab, num_tasks=clients, seed=0)
    loaders = [
        ClientLoader(ds.sample(task=t, num_sequences=12 + 4 * t, seq_len=seq,
                               seed=t), batch_size=4, seed=t)
        for t in range(clients)
    ]
    evals = [ds.to_batch(ds.sample(task=0, num_sequences=8, seq_len=seq,
                                   seed=100))]
    return MeshFederatedTrainer(
        model=model, lora_cfg=LoRAConfig(rank=4, alpha=8),
        fed_cfg=FedConfig(num_clients=clients, rounds=rounds,
                          local_steps=local_steps, method="fedex",
                          participation=participation, weighting=weighting),
        train_cfg=TrainConfig(learning_rate=1e-2, schedule="constant"),
        client_loaders=loaders, eval_batches=evals, seed=0)


class TestMeshTrainer:
    def test_partial_participation_end_to_end(self):
        tr = _mesh_trainer()
        hist = tr.run()
        assert len(hist) == 2
        # every deferred handle resolved by the time run() returns
        for rec in hist:
            assert isinstance(rec.divergence_scaled, float)
            assert rec.divergence_scaled >= 0
            assert np.isfinite(rec.eval_loss)
        # the one-program contract held across sampled rounds
        assert tr.closer.compiled_programs == 1

    def test_rejects_non_mesh_methods(self):
        with pytest.raises(ValueError, match="mesh"):
            tr = _mesh_trainer()
            bad = dataclasses.replace(tr.fed_cfg, method="fedit")
            MeshFederatedTrainer(
                model=tr.model, lora_cfg=tr.lora_cfg, fed_cfg=bad,
                train_cfg=tr.train_cfg, client_loaders=tr.client_loaders,
                eval_batches=tr.eval_batches, seed=0)
