"""Numerical equivalence tests for the sequence-mixing cores: chunked/parallel
training paths vs step-by-step recurrent oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blockwise_attention
from repro.models.ssm import ssd_chunked, ssd_step
from repro.models.xlstm import mlstm_chunked, mlstm_step
from repro.kernels import ref


class TestSSD:
    @pytest.mark.parametrize("chunk", [2, 4, 12])
    def test_chunked_matches_recurrence(self, chunk):
        rng = np.random.default_rng(0)
        B, S, H, P, N = 2, 12, 3, 4, 5
        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, S, H)), jnp.float32)
        a = -jnp.asarray(rng.uniform(0.5, 1.5, size=(H,)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        h = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(S):
            h, y = ssd_step(h, x[:, t], dt[:, t], a, b[:, t], c[:, t])
            ys.append(y)
        y_seq = jnp.stack(ys, 1)
        y_chunk, h_final = ssd_chunked(x, dt, a, b, c, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_final), np.asarray(h),
                                   rtol=1e-4, atol=1e-4)

    def test_state_continuation(self):
        """prefill-then-decode state handoff is exact."""
        rng = np.random.default_rng(1)
        B, S, H, P, N = 1, 8, 2, 4, 3
        mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
        x, b, c = mk((B, S, H, P)), mk((B, S, N)), mk((B, S, N))
        dt = jnp.asarray(rng.uniform(0.2, 0.8, size=(B, S, H)), jnp.float32)
        a = -jnp.ones((H,), jnp.float32)
        _, h_mid = ssd_chunked(x[:, :4], dt[:, :4], a, b[:, :4], c[:, :4], chunk=4)
        y2, h_end = ssd_chunked(x[:, 4:], dt[:, 4:], a, b[:, 4:], c[:, 4:],
                                chunk=4, h0=h_mid)
        y_all, h_all = ssd_chunked(x, dt, a, b, c, chunk=4)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, 4:]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_end), np.asarray(h_all),
                                   rtol=1e-4, atol=1e-4)


class TestMLSTM:
    @pytest.mark.parametrize("chunk", [4, 8, 16])
    def test_chunked_matches_recurrence(self, chunk):
        rng = np.random.default_rng(2)
        B, S, H, D = 2, 16, 3, 8
        mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
        q, v = mk((B, S, H, D)), mk((B, S, H, D))
        k = mk((B, S, H, D)) * (D ** -0.5)
        i_pre = mk((B, S, H))
        lf = jnp.log(jax.nn.sigmoid(mk((B, S, H))))
        st = (jnp.zeros((B, H, D, D)), jnp.zeros((B, H, D)),
              jnp.full((B, H), -jnp.inf))
        hs = []
        s = st
        for t in range(S):
            s, h = mlstm_step(s, q[:, t], k[:, t], v[:, t], i_pre[:, t], lf[:, t])
            hs.append(h)
        h_seq = jnp.stack(hs, 1)
        h_chunk, s_chunk = mlstm_chunked(q, k, v, i_pre, lf, chunk=chunk)
        np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq),
                                   rtol=1e-4, atol=1e-4)
        for x, y in zip(s, s_chunk):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-4, atol=1e-4)

    def test_extreme_gates_stable(self):
        """log-space stabilization: no NaN/inf under extreme input gates."""
        B, S, H, D = 1, 8, 1, 4
        rng = np.random.default_rng(3)
        mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
        q, k, v = mk((B, S, H, D)), mk((B, S, H, D)), mk((B, S, H, D))
        i_pre = jnp.asarray(rng.choice([-50.0, 50.0], size=(B, S, H)), jnp.float32)
        lf = jnp.full((B, S, H), -30.0)
        h, _ = mlstm_chunked(q, k, v, i_pre, lf, chunk=4)
        assert bool(jnp.all(jnp.isfinite(h)))


class TestBlockwiseAttention:
    @pytest.mark.parametrize("sq,sk", [(64, 64), (64, 128), (128, 96)])
    @pytest.mark.parametrize("window", [0, 32])
    def test_vs_oracle(self, sq, sk, window):
        rng = np.random.default_rng(hash((sq, sk, window)) % 2**31)
        B, H, KV, D = 2, 4, 2, 16
        q = jnp.asarray(rng.normal(size=(B, sq, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, sk, KV, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, sk, KV, D)), jnp.float32)
        out = blockwise_attention(q, k, v, causal=True, window=window,
                                  block_size=32)
        kk = jnp.repeat(k, H // KV, axis=2)
        vv = jnp.repeat(v, H // KV, axis=2)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, sq, D)
        kf = kk.transpose(0, 2, 1, 3).reshape(B * H, sk, D)
        vf = vv.transpose(0, 2, 1, 3).reshape(B * H, sk, D)
        orc = ref.flash_swa_ref(qf, kf, vf, causal=True, window=window)
        orc = orc.reshape(B, H, sq, D).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(orc),
                                   rtol=1e-4, atol=1e-4)
