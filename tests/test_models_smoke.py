"""Per-architecture smoke tests (assignment requirement): every assigned arch
instantiates a REDUCED variant (≤2 layers, d_model ≤ 512, ≤4 experts) and runs
one forward/train step on CPU asserting output shapes + no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, LoRAConfig, TrainConfig, get_config
from repro.core import init_lora
from repro.data import make_batch_for
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import init_adamw

ARCHS = list(ASSIGNED)


def _model(name):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    return cfg, build_model(cfg)


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_config_invariants(name):
    cfg = get_config(name).reduced()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg, model = _model(name)
    batch = make_batch_for(cfg, 2, 32, seed=0)
    logits, aux = model.apply(model.init(jax.random.key(0)), batch)
    expect_s = 32 if cfg.family != "vlm" else 32 - cfg.vision_tokens + cfg.vision_tokens
    assert logits.shape[0] == 2
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(name):
    """One full LoRA train step: loss finite, adapters actually move."""
    cfg, model = _model(name)
    params = model.init(jax.random.key(0))
    lcfg = LoRAConfig(rank=4)
    lora = init_lora(jax.random.key(1), params, cfg, lcfg)
    opt = init_adamw(lora)
    batch = make_batch_for(cfg, 2, 32, seed=0)
    step = make_train_step(model, lcfg, TrainConfig(total_steps=10))
    # step=1: warmup gives lr=0 at step 0 by construction
    lora2, opt2, loss, gnorm = jax.jit(step)(params, lora, opt, batch,
                                             jnp.ones((), jnp.int32))
    assert bool(jnp.isfinite(loss)), f"loss not finite: {loss}"
    assert bool(jnp.isfinite(gnorm))
    deltas = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), lora, lora2)
    assert max(jax.tree.leaves(deltas)) > 0.0, "adapters did not update"


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_shapes(name):
    cfg, model = _model(name)
    params = model.init(jax.random.key(0))
    batch = make_batch_for(cfg, 2, 32, seed=0)
    cache = model.init_cache(2, 64)
    logits, cache = model.prefill(params, batch, cache)
    assert bool(jnp.all(jnp.isfinite(logits)))
    pos = 32 + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    tok = batch["tokens"][:, :1]
    logits_d, cache = model.decode_step(params, tok, cache, jnp.asarray(pos))
    assert logits_d.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits_d)))


@pytest.mark.parametrize("name", ["qwen2.5-3b", "granite-8b", "mixtral-8x22b",
                                  "deepseek-v2-236b", "xlstm-1.3b", "zamba2-7b",
                                  "gemma3-12b"])
def test_decode_matches_forward(name):
    """prefill(t[:-1]) + decode(t[-1]) logits == apply(t) last-position logits.

    The strongest cache-correctness check: exercises ring buffers, MLA
    compressed caches, SSM/xLSTM recurrent states and shared-attn caches.
    """
    cfg, model = _model(name)
    params = model.init(jax.random.key(0))
    s = 24
    batch = make_batch_for(cfg, 2, s, seed=0)
    logits_full, _ = model.apply(params, batch)

    prompt = {k: (v[:, :-1] if k in ("tokens",) else v) for k, v in batch.items()
              if k in ("tokens", "vision_embeds", "frames")}
    cache = model.init_cache(2, 64)
    _, cache = model.prefill(params, prompt, cache)
    text_len = prompt["tokens"].shape[1]
    pos = text_len + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    last_tok = batch["tokens"][:, -1:]
    logits_step, _ = model.decode_step(params, last_tok, cache, jnp.asarray(pos))
    # blockwise online-softmax (train path) vs direct softmax (decode path)
    # accumulate ~1e-3 of f32 drift over layers; semantics must agree.
    np.testing.assert_allclose(np.asarray(logits_step[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=5e-3, atol=8e-3)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(logits_step[:, 0]), -1),
        np.argmax(np.asarray(logits_full[:, -1]), -1))


@pytest.mark.parametrize("name", ["mixtral-8x22b", "deepseek-v2-236b"])
def test_moe_impls_agree(name):
    """ragged grouped-GEMM dispatch == dense all-experts oracle."""
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    m_ragged = build_model(cfg, moe_impl="ragged")
    m_dense = build_model(cfg, moe_impl="dense")
    params = m_ragged.init(jax.random.key(0))
    batch = make_batch_for(cfg, 2, 16, seed=0)
    lr, _ = m_ragged.apply(params, batch)
    ld, _ = m_dense.apply(params, batch)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(ld), rtol=2e-3, atol=2e-3)
