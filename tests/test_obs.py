"""Observability subsystem: tracer, metrics, recorder + the traced contracts.

Contracts under test (src/repro/obs/, ISSUE 6):

* Tracer: perf_counter_ns spans nest by containment per thread, threads get
  distinct track ids, and the Chrome trace-event export is structurally what
  Perfetto expects (ph=M metadata, ph=X complete events in µs, ph=i instants).
* Metrics: typed counters/gauges/histograms behind a get-or-create registry
  that refuses to shadow a name with a different metric type.
* Recorder facade: ``off`` is the shared zero-alloc NULL no-op; ``basic``
  collects metrics but no spans (and refuses write_trace); ``trace`` adds
  spans; per-round records are keyed (run, round) so ``set_run`` namespacing
  keeps multi-run processes from merging rounds.
* MetricLogger CSV regression: heterogeneous records (a round that adds eval
  metrics mid-stream) rewrite the file under the union-of-keys header instead
  of crashing DictWriter (fieldnames used to freeze on the FIRST record).
* Comm reconciliation: on a quantized partial-participation run the measured
  BytesLedger agrees with core/comm.round_comm_params pinned to the observed
  delivered count — surfaced as per-round ``comm_match`` + the
  ``comm.reconcile_ok`` counter.
* Deferred-divergence resolution timing, now trace-proven: no host sync (and
  no ``divergence.resolve`` span) inside the close under
  ``jax.transfer_guard_device_to_host``; the resolve span lands AFTER the
  next round's ``ring.write`` spans, and ``scripts/obs_report.py``'s overlap
  check passes on the resulting stream.
* scripts/obs_report.py itself: stream loading, the overlap-invariant
  checker, trace-file validation, and the --check failure modes — exercised
  on synthetic span streams where the timestamps are chosen by hand.
"""

import csv
import importlib.util
import json
import pathlib
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (NULL, Counter, Gauge, Histogram, MetricsRegistry,
                       NullRecorder, Recorder, Tracer, make_recorder)
from repro.util.logging import MetricLogger

_OBS_REPORT = (pathlib.Path(__file__).resolve().parents[1]
               / "scripts" / "obs_report.py")
_spec = importlib.util.spec_from_file_location("obs_report", _OBS_REPORT)
obs_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(obs_report)


# ---------------------------------------------------------------------------
# tracer


class TestTracer:
    def test_span_records_interval_and_args(self):
        tr = Tracer()
        with tr.span("outer", cat="test", round=3):
            with tr.span("inner", cat="test"):
                pass
        # recorded on exit: inner first
        assert [s["name"] for s in tr.spans] == ["inner", "outer"]
        inner, outer = tr.spans
        assert outer["args"] == {"round": 3}
        assert outer["ts"] >= 0 and outer["dur"] >= 0
        # nesting by containment: [inner] ⊆ [outer] on the same thread
        assert inner["tid"] == outer["tid"]
        assert inner["ts"] >= outer["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]

    def test_threads_get_distinct_track_ids(self):
        tr = Tracer()

        def record(name):
            with tr.span(name):
                pass

        t = threading.Thread(target=record, args=("worker",))
        record("main")
        t.start()
        t.join()
        tids = {s["name"]: s["tid"] for s in tr.spans}
        assert tids["main"] != tids["worker"]

    def test_instant_events(self):
        tr = Tracer()
        tr.instant("drop", cat="ring", client=7)
        (e,) = tr.events
        assert e["name"] == "drop" and e["args"] == {"client": 7}

    def test_chrome_export_structure(self, tmp_path):
        tr = Tracer()
        with tr.span("close.dispatch", cat="engine", round=0):
            pass
        tr.instant("ring.take", cat="ring", round=0)
        chrome = tr.to_chrome(process_name="proc")
        events = chrome["traceEvents"]
        metas = [e for e in events if e["ph"] == "M"]
        assert metas[0]["args"]["name"] == "proc"
        (x,) = [e for e in events if e["ph"] == "X"]
        assert x["name"] == "close.dispatch"
        # µs conversion from the ns record
        assert x["ts"] == pytest.approx(tr.spans[0]["ts"] / 1e3)
        assert x["dur"] == pytest.approx(tr.spans[0]["dur"] / 1e3)
        (i,) = [e for e in events if e["ph"] == "i"]
        assert i["name"] == "ring.take" and i["s"] == "t"
        path = tmp_path / "trace.json"
        tr.write_chrome_trace(str(path))
        assert json.load(open(path))["traceEvents"]
        assert obs_report.check_trace_file(str(path)) == []


# ---------------------------------------------------------------------------
# metrics


class TestMetrics:
    def test_counter_is_monotonic(self):
        c = Counter("n")
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(ValueError, match="cannot decrease"):
            c.inc(-1)

    def test_gauge_last_write_wins(self):
        g = Gauge("occ")
        g.set(3)
        g.set(1)
        assert g.value == 1

    def test_histogram_summary(self):
        h = Histogram("lat")
        for v in (1, 2, 3):
            h.observe(v)
        s = h.summary()
        assert s["count"] == 3 and s["sum"] == 6.0
        assert s["min"] == 1.0 and s["max"] == 3.0
        assert s["mean"] == pytest.approx(2.0)
        assert s["std"] == pytest.approx(np.sqrt(2.0 / 3.0))
        assert Histogram("empty").summary() == {"count": 0}

    def test_registry_get_or_create_and_type_guard(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(TypeError, match="is a Counter"):
            reg.gauge("x")
        snap = reg.snapshot()
        assert set(snap) == {"counters", "gauges", "histograms"}
        assert snap["counters"] == {"x": 0}


# ---------------------------------------------------------------------------
# recorder facade


class TestRecorderFacade:
    def test_off_is_the_shared_null_singleton(self):
        assert make_recorder("off") is NULL
        assert isinstance(NULL, NullRecorder)
        assert NULL.enabled is False and NULL.tracing is False

    def test_null_recorder_noop_contract(self, tmp_path):
        # callable unconditionally: spans usable, metrics inert, no files
        with NULL.span("anything", round=1):
            NULL.counter("c").inc(10)
            NULL.gauge("g").set(5)
            NULL.hist("h").observe(1.0)
        NULL.event("e", client=0)
        NULL.round_set(0, x=1)
        NULL.round_inc(0, "y")
        assert NULL.round_records() == []
        NULL.write_trace(str(tmp_path / "t.json"))
        NULL.write_metrics(str(tmp_path / "m.jsonl"))
        assert list(tmp_path.iterdir()) == []

    def test_mode_validation(self):
        with pytest.raises(ValueError, match="obs mode"):
            make_recorder("verbose")
        with pytest.raises(ValueError, match="basic|trace"):
            Recorder("off")

    def test_basic_mode_collects_metrics_but_no_spans(self, tmp_path):
        rec = make_recorder("basic")
        assert rec.enabled and not rec.tracing and rec.tracer is None
        with rec.span("close.dispatch", round=0):
            rec.counter("ring.evictions").inc()
        rec.event("ring.take", round=0)
        recs = rec.metrics_records()
        assert [r["type"] for r in recs[:2]] == ["meta", "counters"]
        assert not any(r["type"] in ("span", "event") for r in recs)
        assert recs[1]["counters"] == {"ring.evictions": 1}
        with pytest.raises(ValueError, match="write_trace"):
            rec.write_trace(str(tmp_path / "t.json"))

    def test_rounds_keyed_by_run_label(self):
        rec = Recorder("basic")
        rec.set_run("scenario-1")
        rec.round_set(0, delivered=3)
        rec.round_inc(0, "deadline_drops")
        rec.set_run("scenario-2")
        rec.round_set(0, delivered=2)
        recs = rec.round_records()
        assert len(recs) == 2  # round 0 of each run stays distinct
        assert recs[0] == {"run": "scenario-1", "round": 0, "delivered": 3,
                           "deadline_drops": 1}
        assert recs[1]["run"] == "scenario-2" and recs[1]["delivered"] == 2

    def test_trace_mode_stream_and_exports(self, tmp_path):
        rec = Recorder("trace")
        rec.set_run("r")
        with rec.span("ring.write", cat="ring", round=1, client=0):
            pass
        rec.event("ring.begin", cat="ring", round=1)
        mpath, tpath = tmp_path / "m.jsonl", tmp_path / "t.json"
        rec.write_metrics(str(mpath))
        rec.write_trace(str(tpath))
        recs = obs_report.load_stream(str(mpath))
        meta, counters, rounds, spans, events = obs_report.split_stream(recs)
        assert meta is not None and meta["backend"] == jax.default_backend()
        assert counters is not None
        (s,) = spans
        assert s["name"] == "ring.write" and s["run"] == "r"
        assert s["args"] == {"round": 1, "client": 0}
        assert isinstance(s["ts_us"], float) and s["dur_us"] >= 0
        (e,) = events
        assert e["name"] == "ring.begin"
        assert obs_report.check_trace_file(str(tpath)) == []
        assert any("obs mode=trace" in ln for ln in rec.summary_lines())


# ---------------------------------------------------------------------------
# satellite: MetricLogger CSV union-of-keys regression


class TestMetricLoggerCSV:
    def test_new_keys_mid_stream_rewrite_under_union_header(self, tmp_path):
        """A record introducing a new key (eval metrics on round boundaries)
        used to raise ValueError from DictWriter, whose fieldnames froze on
        the first record. Now: union header, old rows blank-filled."""
        path = tmp_path / "m.csv"
        ml = MetricLogger(csv_path=str(path))
        ml.log(0, {"loss": 1.0})
        ml.log(1, {"loss": 0.5, "eval_acc": 0.25})  # new key mid-stream
        ml.log(2, {"loss": 0.4})                    # back to the narrow shape
        ml.close()
        with open(path) as f:
            reader = csv.DictReader(f)
            assert reader.fieldnames == ["step", "wall_s", "loss", "eval_acc"]
            rows = list(reader)
        assert [r["loss"] for r in rows] == ["1.0", "0.5", "0.4"]
        assert rows[0]["eval_acc"] == ""      # predates the column
        assert rows[1]["eval_acc"] == "0.25"
        assert rows[2]["eval_acc"] == ""      # restval fills the gap
        assert len(ml.history) == 3

    def test_csvless_logger_still_accumulates(self):
        ml = MetricLogger(csv_path=None)
        ml.log(0, {"loss": 1.0})
        ml.log(1, {"loss": 0.5, "extra": 2})
        assert len(ml.history) == 2
        ml.close()


# ---------------------------------------------------------------------------
# satellite: deferred-divergence resolution timing, trace-proven


def _traced_engine(c=3, m=8, r=2, n=6, seed=0, **kw):
    from repro.core.engine import RoundCloseEngine

    rng = np.random.default_rng(seed)
    mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    params = {"blk": {"q_proj": {"kernel": mk((m, n))}}}
    template = {"blk": {"q_proj": {"a": mk((m, r)), "b": mk((r, n))}}}
    loras = [{"blk": {"q_proj": {"a": mk((m, r)), "b": mk((r, n))}}}
             for _ in range(c)]
    rec = Recorder("trace")
    eng = RoundCloseEngine(params, template, c_max=c, scale=2.0,
                           backend="jnp", recorder=rec, **kw)
    return eng, rec, params, loras


class TestDivergenceResolutionTiming:
    def test_resolve_span_lands_after_next_rounds_ring_writes(self):
        """The traced twin of the transfer-guard contract: the close emits
        close.dispatch but NO divergence.resolve span; round 1's uplinks
        stream into the ring; only then does resolve() stamp its span — so
        the resolve timestamp sits after every round-1 ring.write, and
        obs_report's overlap check proves round 0's close window intersects
        round 1's writes."""
        eng, rec, params, loras = _traced_engine(depth=2)
        eng.buffers.begin_round({i: i for i in range(3)}, round_id=0)
        for i, l in enumerate(loras):
            eng.buffers.write(i, l, round_id=0)
        with jax.transfer_guard_device_to_host("disallow"):
            _, params1, div0 = eng.close(params, [0, 1, 2], round_id=0)
        names = [s["name"] for s in rec.tracer.spans]
        assert "close.dispatch" in names
        assert "divergence.resolve" not in names, \
            "close resolved the divergence eagerly — host sync in the close"

        # round 1's uplinks stream in while round 0's close is in flight
        eng.buffers.begin_round({i: i for i in range(3)}, round_id=1)
        for i, l in enumerate(loras):
            eng.buffers.write(i, l, round_id=1)
        div0.resolve()  # the round boundary — the only host sync
        spans = rec.tracer.spans
        resolve0 = next(s for s in spans if s["name"] == "divergence.resolve")
        assert resolve0["args"]["round"] == 0
        r1_writes = [s for s in spans if s["name"] == "ring.write"
                     and s["args"]["round"] == 1]
        assert len(r1_writes) == 3
        for w in r1_writes:
            assert w["ts"] < resolve0["ts"], \
                "a round-1 uplink landed after round 0's resolve"

        # close round 1 too, then run the report's own invariant checker
        _, _, div1 = eng.close(params1, [0, 1, 2], round_id=1)
        div1.resolve()
        _, _, _, span_recs, _ = obs_report.split_stream(rec.metrics_records())
        proven, failures = obs_report.check_overlap(span_recs)
        assert failures == []
        assert len(proven) == 1 and "round=0→1" in proven[0]

    def test_round_records_carry_the_latency_split(self):
        eng, rec, params, loras = _traced_engine()
        for rnd in range(2):
            eng.buffers.begin_round({i: i for i in range(3)}, round_id=rnd)
            for i, l in enumerate(loras):
                eng.buffers.write(i, l, round_id=rnd)
            _, params, div = eng.close(params, [0, 1, 2], round_id=rnd)
            div.resolve()
        recs = {r["round"]: r for r in rec.round_records()}
        for rnd in range(2):
            r = recs[rnd]
            assert r["close_dispatch_us"] > 0
            assert r["close_block_us"] > 0
            assert r["divergence"] >= 0
        # one compile per signature: round 0 misses, round 1 hits
        counters = rec.metrics.snapshot()["counters"]
        (miss_key,) = [k for k in counters if k.startswith("engine.compile_miss")]
        assert counters[miss_key] == 1
        assert recs[0]["compile_miss"] == 1 and recs[1]["compile_miss"] == 0
        hist = rec.metrics.snapshot()["histograms"]
        assert hist["engine.close_dispatch_us"]["count"] == 2
        assert hist["engine.close_block_us"]["count"] == 2


# ---------------------------------------------------------------------------
# satellite: ledger ↔ comm-table reconciliation on a quantized partial round


class TestCommReconciliation:
    def test_int8_partial_participation_rounds_reconcile(self):
        """The measured BytesLedger and core/comm.py's closed form are
        independent accountings of the same round; with int8 uplink AND
        partial participation they still agree on param counts (bytes are
        codec-dependent: int8 uplinks measure well under 4 B/param)."""
        import dataclasses

        from repro.configs import (FedConfig, LoRAConfig, TrainConfig,
                                   get_config)
        from repro.core import FederatedTrainer
        from repro.data import ClientLoader, SyntheticLM
        from repro.models import build_model

        cfg = dataclasses.replace(get_config("paper-tiny"), dtype="float32",
                                  vocab_size=16)
        ds = SyntheticLM(vocab=16, num_tasks=4, seed=0)
        loaders = [ClientLoader(ds.sample(task=t, num_sequences=12,
                                          seq_len=16, seed=t),
                                batch_size=4, seed=t) for t in range(4)]
        tr = FederatedTrainer(
            model=build_model(cfg), lora_cfg=LoRAConfig(rank=4, alpha=8),
            fed_cfg=FedConfig(num_clients=4, rounds=2, local_steps=2,
                              method="fedex", participation=0.5,
                              weighting="examples", quantize_uplink="int8",
                              obs="basic"),
            train_cfg=TrainConfig(learning_rate=1e-2, schedule="constant"),
            client_loaders=loaders, eval_batches=[], seed=0)
        tr.run()

        rec = tr.recorder
        assert rec.enabled and rec.mode == "basic"
        rounds = rec.round_records()
        matched = [r for r in rounds if "comm_match" in r]
        assert len(matched) == 2, f"expected 2 reconciled rounds: {rounds}"
        for r in matched:
            assert r["comm_match"] == 1, f"ledger ≠ comm table: {r}"
            assert r["delivered"] == 2  # ⌈0.5·4⌉ sampled, none dropped
            assert r["uplink_params"] > 0
            # int8 uplink: measured bytes well under fp32's 4 B/param
            assert r["uplink_bytes"] < 4 * r["uplink_params"]
            assert r["downlink_bytes"] > 0
        counters = rec.metrics.snapshot()["counters"]
        assert counters.get("comm.reconcile_ok") == 2
        assert "comm.reconcile_mismatch" not in counters

    def test_participants_pin_in_round_comm_params(self):
        """The reconciliation anchor: `participants` overrides the ceil
        estimate with the observed delivered count, and out-of-range pins
        are rejected."""
        from repro.core.comm import MatrixSpec, round_comm_params

        mats = [MatrixSpec("q", 8, 8)]
        # pinning to the count ⌈0.3·10⌉ would estimate gives the same table
        est = round_comm_params("fedex", mats, 2, 10,
                                participation_fraction=0.3)
        assert round_comm_params("fedex", mats, 2, 10, participants=3) == est
        # a realized count the estimate can't know (dropout) changes it
        dropped = round_comm_params("fedex", mats, 2, 10, participants=2)
        assert dropped["uplink"] < est["uplink"]
        with pytest.raises(ValueError, match="participants"):
            round_comm_params("fedex", mats, 2, 10, participants=0)
        with pytest.raises(ValueError, match="participants"):
            round_comm_params("fedex", mats, 2, 10, participants=11)


# ---------------------------------------------------------------------------
# scripts/obs_report.py on synthetic streams


def _span(name, ts, dur, rnd, run=None):
    return {"type": "span", "name": name, "cat": "t", "run": run, "tid": 0,
            "ts_us": float(ts), "dur_us": float(dur), "args": {"round": rnd}}


def _overlapping_spans(run=None):
    """Round 0 closes over [100, 500]us; round 1's writes land inside it."""
    return [
        _span("close.dispatch", 100, 50, 0, run),
        _span("ring.write", 200, 10, 1, run),
        _span("ring.write", 300, 10, 1, run),
        _span("divergence.resolve", 480, 20, 0, run),
        _span("close.dispatch", 600, 40, 1, run),
        _span("divergence.resolve", 700, 10, 1, run),
    ]


def _closed_round(rnd, run=None, **over):
    rec = {"type": "round", "run": run, "round": rnd, "sampled": 3,
           "delivered": 3, "close_dispatch_us": 50.0, "close_block_us": 20.0,
           "divergence": 0.1, "ring_evictions": 0, "stale_drops": 0,
           "uplink_bytes": 100, "downlink_bytes": 200, "comm_match": 1}
    rec.update(over)
    return rec


class TestObsReport:
    def test_load_stream_rejects_bad_json(self, tmp_path):
        path = tmp_path / "m.jsonl"
        path.write_text('{"type": "meta"}\n\nnot json\n')
        with pytest.raises(SystemExit, match="bad JSON"):
            obs_report.load_stream(str(path))

    def test_overlap_check_proves_the_good_stream(self):
        proven, failures = obs_report.check_overlap(_overlapping_spans())
        assert failures == []
        assert len(proven) == 1
        assert "2/2 ring.write" in proven[0]

    def test_overlap_check_fails_when_writes_miss_the_window(self):
        """A host sync inside the close pulls divergence.resolve before the
        next round's writes — the window shuts early and the check fails."""
        spans = _overlapping_spans()
        for s in spans:
            if s["name"] == "ring.write":
                s["ts_us"] = 550.0  # after the [100, 500] window shuts
        proven, failures = obs_report.check_overlap(spans)
        assert proven == []
        assert len(failures) == 1 and "did not overlap" in failures[0]

    def test_overlap_check_never_crosses_runs(self):
        """Round 0 of run A and round 1 of run B are NOT a consecutive pair."""
        spans = [_span("close.dispatch", 100, 50, 0, "A"),
                 _span("divergence.resolve", 480, 20, 0, "A"),
                 _span("ring.write", 200, 10, 1, "B"),
                 _span("close.dispatch", 600, 40, 1, "B"),
                 _span("divergence.resolve", 700, 10, 1, "B")]
        proven, failures = obs_report.check_overlap(spans)
        assert proven == [] and failures == []

    def test_run_checks_green_path(self):
        failures = obs_report.run_checks(
            {"type": "meta"}, {"type": "counters"},
            [_closed_round(0), _closed_round(1)],
            _overlapping_spans(), None)
        assert failures == []

    def test_run_checks_failure_modes(self):
        meta, counters = {"type": "meta"}, {"type": "counters"}
        rounds = [_closed_round(0), _closed_round(1)]
        spans = _overlapping_spans()

        assert any("no meta" in f for f in obs_report.run_checks(
            None, counters, rounds, spans, None))
        assert any("no round records" in f for f in obs_report.run_checks(
            meta, counters, [], [], None))

        incomplete = [_closed_round(0), _closed_round(1)]
        del incomplete[0]["close_block_us"]
        (f,) = obs_report.run_checks(meta, counters, incomplete, spans, None)
        assert "missing" in f and "close_block_us" in f

        mismatch = [_closed_round(0, comm_match=0), _closed_round(1)]
        (f,) = obs_report.run_checks(meta, counters, mismatch, spans, None)
        assert "closed form" in f

        # spans that prove nothing (no consecutive closed pair with writes)
        lonely = [_span("close.dispatch", 100, 50, 0),
                  _span("divergence.resolve", 480, 20, 0)]
        assert any("nothing proves" in f for f in obs_report.run_checks(
            meta, counters, rounds, lonely, None))

        # --trace against a span-free (obs=basic) stream
        assert any("no spans" in f for f in obs_report.run_checks(
            meta, counters, rounds, [], "trace.json"))

    def test_trace_file_validation(self, tmp_path):
        good = tmp_path / "good.json"
        good.write_text(json.dumps({"traceEvents": [
            {"name": "t", "ph": "M", "args": {}},
            {"name": "s", "ph": "X", "ts": 1.0, "dur": 2.0}]}))
        assert obs_report.check_trace_file(str(good)) == []

        empty = tmp_path / "empty.json"
        empty.write_text(json.dumps({"traceEvents": []}))
        assert any("no traceEvents" in p
                   for p in obs_report.check_trace_file(str(empty)))

        spanless = tmp_path / "spanless.json"
        spanless.write_text(json.dumps({"traceEvents": [
            {"name": "t", "ph": "M"}]}))
        assert any("no complete" in p
                   for p in obs_report.check_trace_file(str(spanless)))

        bad_x = tmp_path / "badx.json"
        bad_x.write_text(json.dumps({"traceEvents": [
            {"name": "s", "ph": "X", "ts": "soon"}]}))
        assert any("without numeric ts/dur" in p
                   for p in obs_report.check_trace_file(str(bad_x)))

        assert any("unreadable" in p for p in
                   obs_report.check_trace_file(str(tmp_path / "missing.json")))

    def test_main_on_a_real_recorder_stream(self, tmp_path, capsys):
        """End-to-end: a live traced engine run → write_metrics/write_trace →
        obs_report.main --check exits 0."""
        eng, rec, params, loras = _traced_engine()
        slots = {i: i for i in range(3)}
        # the trainers' interleaving: round 1's uplinks stream into the ring
        # BEFORE round 0's divergence resolves
        eng.buffers.begin_round(slots, round_id=0)
        for i, l in enumerate(loras):
            eng.buffers.write(i, l, round_id=0)
        _, params, div0 = eng.close(params, [0, 1, 2], round_id=0)
        eng.buffers.begin_round(slots, round_id=1)
        for i, l in enumerate(loras):
            eng.buffers.write(i, l, round_id=1)
        div0.resolve()
        _, _, div1 = eng.close(params, [0, 1, 2], round_id=1)
        div1.resolve()
        # the trainer's reconciliation fields, stamped here by hand (the
        # engine alone has no ledger)
        for rnd in range(2):
            rec.round_set(rnd, ring_evictions=0, stale_drops=0,
                          uplink_bytes=1, downlink_bytes=1, comm_match=1)
        mpath, tpath = tmp_path / "m.jsonl", tmp_path / "t.json"
        rec.write_metrics(str(mpath))
        rec.write_trace(str(tpath))
        code = obs_report.main([str(mpath), "--trace", str(tpath), "--check"])
        out = capsys.readouterr().out
        assert code == 0, out
        assert "CHECK OK" in out and "overlap invariant" in out
