"""AdamW / schedule / clipping unit tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw_update, clip_by_global_norm, init_adamw, lr_at


def test_adamw_matches_manual_reference():
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]], jnp.float32)}
    st = init_adamw(p)
    lr, b1, b2, eps, wd = 0.01, 0.9, 0.999, 1e-8, 0.1
    new_p, st2 = adamw_update(g, st, p, learning_rate=lr, beta1=b1, beta2=b2,
                              eps=eps, weight_decay=wd)
    # manual step 1
    gw = np.asarray(g["w"])
    pw = np.asarray(p["w"])
    m = (1 - b1) * gw
    v = (1 - b2) * gw ** 2
    m_hat = m / (1 - b1)
    v_hat = v / (1 - b2)
    expect = pw - lr * (m_hat / (np.sqrt(v_hat) + eps) + wd * pw)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-6)
    assert int(st2.step) == 1


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    p = {"x": jnp.zeros(3)}
    st = init_adamw(p)
    for _ in range(400):
        g = {"x": 2 * (p["x"] - target)}
        p, st = adamw_update(g, st, p, learning_rate=0.05, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(target), atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((3,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    expect_norm = np.sqrt(3 * 9 + 4 * 16)
    np.testing.assert_allclose(float(norm), expect_norm, rtol=1e-6)
    total = np.sqrt(sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_clip_noop_when_small():
    g = {"a": jnp.asarray([0.1])}
    clipped, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.1], rtol=1e-6)


def test_schedules():
    kw = dict(base_lr=1.0, total_steps=100, warmup_ratio=0.1)
    # warmup ramps
    assert float(lr_at(0, **kw, kind="cosine")) == 0.0
    assert 0 < float(lr_at(5, **kw, kind="cosine")) < 1.0
    # peak right after warmup
    assert float(lr_at(10, **kw, kind="cosine")) > 0.99
    # cosine ends near 0; linear ends at 0; constant stays 1
    assert float(lr_at(100, **kw, kind="cosine")) < 0.01
    assert float(lr_at(100, **kw, kind="linear")) < 0.01
    assert float(lr_at(100, **kw, kind="constant")) == 1.0


def test_frozen_base_has_no_moments():
    """LoRA-only optimizer state (the memory argument of the paper §3)."""
    lora = {"a": jnp.zeros((8, 2)), "b": jnp.zeros((2, 8))}
    st = init_adamw(lora)
    from repro.util.tree import count_params
    assert count_params(st.mu) == count_params(lora)
