"""Property-based tests (hypothesis) for the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    fedex_aggregate,
    fedit_aggregate,
    ffa_aggregate,
    product_mean,
    residual_factors,
)

_dims = st.integers(min_value=1, max_value=24)
_rank = st.integers(min_value=1, max_value=6)
_clients = st.integers(min_value=1, max_value=6)
_seed = st.integers(min_value=0, max_value=2**31 - 1)


def _mk(k, m, r, n, seed, same_a=False):
    rng = np.random.default_rng(seed)
    a0 = rng.normal(size=(m, r))
    out = []
    for i in range(k):
        a = a0 if same_a else rng.normal(size=(m, r))
        out.append({"w": {"a": jnp.asarray(a, jnp.float32),
                          "b": jnp.asarray(rng.normal(size=(r, n)), jnp.float32)}})
    return out


@settings(max_examples=40, deadline=None)
@given(k=_clients, m=_dims, r=_rank, n=_dims, seed=_seed)
def test_fedex_exact_for_any_shape(k, m, r, n, seed):
    """Paper Eq. 7–9 holds for EVERY (k, m, r, n)."""
    loras = _mk(k, m, r, n, seed)
    g, res = fedex_aggregate(loras)
    ideal = product_mean(loras)["w"]
    got = jnp.matmul(g["w"]["a"], g["w"]["b"]) + res["w"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ideal),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=40, deadline=None)
@given(k=_clients, m=_dims, r=_rank, n=_dims, seed=_seed)
def test_residual_rank_bound(k, m, r, n, seed):
    """rank(ΔW_res) ≤ (k+1)·r — the communication-protocol guarantee."""
    loras = _mk(k, m, r, n, seed)
    _, res = fedex_aggregate(loras)
    rank = np.linalg.matrix_rank(np.asarray(res["w"]), tol=1e-4)
    assert rank <= min((k + 1) * r, m, n)


@settings(max_examples=40, deadline=None)
@given(k=st.integers(min_value=2, max_value=6), m=_dims, r=_rank, n=_dims, seed=_seed)
def test_ffa_is_exact_when_a_shared(k, m, r, n, seed):
    """FFA-LoRA: with identical a, factor averaging IS exact (zero residual)."""
    loras = _mk(k, m, r, n, seed, same_a=True)
    g = ffa_aggregate(loras)
    ideal = product_mean(loras)["w"]
    got = jnp.matmul(g["w"]["a"], g["w"]["b"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ideal),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=30, deadline=None)
@given(k=_clients, m=_dims, r=_rank, n=_dims, seed=_seed)
def test_factored_residual_lossless(k, m, r, n, seed):
    loras = _mk(k, m, r, n, seed)
    _, res = fedex_aggregate(loras)
    L, R = residual_factors([l["w"] for l in loras])
    np.testing.assert_allclose(np.asarray(L @ R), np.asarray(res["w"]),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(m=_dims, r=_rank, n=_dims, seed=_seed, scale=st.floats(0.1, 10.0))
def test_fedit_scale_invariant_deviation(m, r, n, seed, scale):
    """Deviation is bilinear: scaling all factors by s scales ΔW_res by s²."""
    loras = _mk(3, m, r, n, seed)
    _, res1 = fedex_aggregate(loras)
    scaled = jax.tree.map(lambda x: x * jnp.sqrt(scale), loras)
    _, res2 = fedex_aggregate(scaled)
    np.testing.assert_allclose(np.asarray(res2["w"]),
                               scale * np.asarray(res1["w"]),
                               rtol=5e-3, atol=5e-3)


@settings(max_examples=40, deadline=None)
@given(k=st.integers(min_value=2, max_value=6), m=_dims, r=_rank, n=_dims,
       seed=_seed)
def test_weighted_fedex_exact_for_any_weights(k, m, r, n, seed):
    """fedsrv regime: Σwᵢaᵢbᵢ = āb̄ + ΔW_res for ANY example-count weights."""
    loras = _mk(k, m, r, n, seed)
    counts = np.random.default_rng(seed + 1).integers(1, 1000, size=k).tolist()
    w = [c / sum(counts) for c in counts]
    g, res = fedex_aggregate(loras, counts)  # raw counts: normalized inside
    ideal = sum(wi * jnp.matmul(l["w"]["a"], l["w"]["b"])
                for wi, l in zip(w, loras))
    got = jnp.matmul(g["w"]["a"], g["w"]["b"]) + res["w"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ideal),
                               rtol=2e-4, atol=2e-4)
