"""HTTP federation service (fedsrv/server.py + client.py + wire.py).

Contracts under test:

* Wire frame round-trip: ``payload_to_wire``/``payload_from_wire`` is exact
  for every codec tier, and every malformation (magic, truncated header,
  truncated body, bad dtype, descriptor/byte disagreement, trailing bytes)
  raises ``TransportError reason="wire"`` — never a frombuffer crash.
* End-to-end exactness: rounds driven through FedClient → real socket →
  defended decode → ring → engine close are BITWISE identical to an
  in-process engine replay of the same deltas (same seed), and the server's
  W0 digest matches the twin's folded base — the residual-fold witness.
* HTTP status mapping: 401 auth, 403 unknown client, 400 wire/addressing,
  409 stale/replay, 410 done, 422 quarantine (with the reason landing in
  ``uplink.quarantined[reason]``), 429 quota.
* Deadline mapping: ``FedConfig.round_deadline`` means wall-seconds in
  serve mode (SimClock pinned to ``time.monotonic``); an expired round
  closes at quorum from a ``tick()``/healthz poll with no further POSTs.
* Ledger-vs-wire reconciliation under HTTP framing: request-line + header
  + frame-envelope octets live under the separate ``http_overhead``
  direction, and ``uplink.http_bytes`` equals payload-direction ledger
  bytes + ``uplink.http_overhead_bytes`` exactly (satellite fix).
* SimClock wall mode: monotone, advance() floors, state round-trips.
"""

import time
import urllib.error
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, ServeConfig
from repro.core.engine import RoundCloseEngine
from repro.fedsrv.client import FedClient
from repro.fedsrv.registry import SimClock
from repro.core.hetero import pad_adapters
from repro.fedsrv.server import (FederationServer, hetero_w0_digest,
                                 start_http_server, w0_digest)
from repro.fedsrv.transport import (AdapterCodec, Payload, StaleUplinkError,
                                    TransportError)
from repro.fedsrv.wire import payload_from_wire, payload_to_wire
from repro.util.tree import flatten_with_paths

M, N, R = 8, 6, 2


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {"blk": {"q": {"kernel": jnp.asarray(
        rng.normal(size=(M, N)), jnp.float32)}}}


def _template():
    return {"blk": {"q": {"a": jnp.zeros((M, R), jnp.float32),
                          "b": jnp.zeros((R, N), jnp.float32)}}}


def _delta(rnd, cid, seed=42):
    g = np.random.default_rng([seed, rnd, cid])
    return {"blk": {"q": {"a": g.normal(size=(M, R)).astype(np.float32),
                          "b": g.normal(size=(R, N)).astype(np.float32)}}}


def _ragged_delta(rnd, cid, r, seed=42):
    """A rank-r delta exactly as a hetero client would uplink it — TRUE
    rank-r factor widths, no padding (the server pads at decode)."""
    g = np.random.default_rng([seed, rnd, cid])
    return {"blk": {"q": {"a": g.normal(size=(M, r)).astype(np.float32),
                          "b": g.normal(size=(r, N)).astype(np.float32)}}}


def _bitwise(a, b):
    fa, fb = flatten_with_paths(a), flatten_with_paths(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=f"at {k}")


@pytest.fixture
def served():
    """A booted 3-client 2-round server on an ephemeral port + its URL.
    Token auth on; obs trace so counters/records are assertable."""
    fed_cfg = FedConfig(num_clients=3, rounds=2, obs="trace")
    srv = FederationServer(_params(), _template(), scale=0.5,
                           fed_cfg=fed_cfg,
                           serve_cfg=ServeConfig(port=0, token="tok",
                                                 quota_per_round=2))
    httpd = start_http_server(srv, port=0)
    url = f"http://127.0.0.1:{httpd.server_address[1]}"
    yield srv, url
    httpd.shutdown()


class TestWireFrame:
    @pytest.mark.parametrize("codec", ["none", "fp16", "int8"])
    def test_round_trip_exact(self, codec):
        c = AdapterCodec(codec)
        payload = c.encode(_delta(0, 1), round_id=3, client_id=1)
        back = payload_from_wire(payload_to_wire(payload))
        assert (back.round_id, back.client_id, back.codec,
                back.direction) == (3, 1, codec, "uplink")
        _bitwise(c.decode(back), c.decode(payload))

    def test_declared_shape_survives_framing(self):
        # a truncated buffer that still DECLARES its full shape must be
        # quarantined by the decode boundary after crossing the wire
        c = AdapterCodec("none")
        payload = c.encode(_delta(0, 0), round_id=0, client_id=0)
        path, enc = next(iter(payload.tensors.items()))
        cut = type(enc)(enc.data.reshape(-1)[:-2], enc.scale,
                        tuple(enc.data.shape))
        bad = Payload(payload.round_id, payload.client_id, payload.direction,
                      payload.codec, {**payload.tensors, path: cut})
        back = payload_from_wire(payload_to_wire(bad))
        with pytest.raises(TransportError) as ei:
            c.decode(back)
        assert ei.value.reason == "bytes"

    @pytest.mark.parametrize("mangle", [
        lambda b: b"XXXX" + b[4:],                      # magic
        lambda b: b[:6],                                # truncated header
        lambda b: b[:-3],                               # truncated body
        lambda b: b + b"\x00\x00",                      # trailing garbage
        lambda b: b[:4] + b"\xff\xff\xff\xff" + b[8:],  # absurd header len
    ])
    def test_malformed_frames_raise_wire_reason(self, mangle):
        payload = AdapterCodec("none").encode(_delta(0, 0), round_id=0,
                                              client_id=0)
        with pytest.raises(TransportError) as ei:
            payload_from_wire(mangle(payload_to_wire(payload)))
        assert ei.value.reason == "wire"

    def test_bad_dtype_rejected(self):
        payload = AdapterCodec("none").encode(_delta(0, 0), round_id=0,
                                              client_id=0)
        blob = payload_to_wire(payload)
        assert b"float32" in blob
        with pytest.raises(TransportError) as ei:
            payload_from_wire(blob.replace(b"float32", b"float64", 1))
        assert ei.value.reason == "wire"


class TestServerEndToEnd:
    def test_rounds_close_bitwise_vs_inprocess_twin(self, served):
        srv, url = served
        clients = [FedClient(url, i, token="tok") for i in range(3)]
        for rnd in range(2):
            for i, c in enumerate(clients):
                resp = c.submit_delta(_delta(rnd, i), round_id=rnd)
                assert resp["status"] == "accepted"
        pull = clients[0].pull_latest()
        assert pull.version == 2

        eng = RoundCloseEngine(_params(), _template(), c_max=3, scale=0.5,
                               backend="auto")
        tp, tl = _params(), None
        for rnd in range(2):
            eng.buffers.begin_round({i: i for i in range(3)}, round_id=rnd)
            for i in range(3):
                eng.buffers.write(i, _delta(rnd, i), round_id=rnd)
            tl, tp, div = eng.close(tp, [0, 1, 2], round_id=rnd)
        _bitwise(pull.lora, tl)
        assert pull.w0_digest == w0_digest(eng.specs, tp)

    def test_done_server_rejects_with_410(self, served):
        srv, url = served
        clients = [FedClient(url, i, token="tok") for i in range(3)]
        for rnd in range(2):
            for i, c in enumerate(clients):
                c.submit_delta(_delta(rnd, i), round_id=rnd)
        assert clients[0].health()["status"] == "done"
        with pytest.raises(StaleUplinkError):
            clients[0].submit_delta(_delta(5, 0), round_id=5)

    def test_examples_weighting_matches_weighted_twin(self):
        fed_cfg = FedConfig(num_clients=3, rounds=1, weighting="examples")
        srv = FederationServer(_params(), _template(), scale=0.5,
                               fed_cfg=fed_cfg,
                               serve_cfg=ServeConfig(port=0))
        httpd = start_http_server(srv, port=0)
        url = f"http://127.0.0.1:{httpd.server_address[1]}"
        try:
            ns = [120, 40, 200]
            for i in range(3):
                FedClient(url, i, num_examples=ns[i]).submit_delta(
                    _delta(0, i), round_id=0)
            pull = FedClient(url, 0).pull_latest()
        finally:
            httpd.shutdown()
        eng = RoundCloseEngine(_params(), _template(), c_max=3, scale=0.5,
                               backend="auto")
        eng.buffers.begin_round({i: i for i in range(3)}, round_id=0)
        for i in range(3):
            eng.buffers.write(i, _delta(0, i), round_id=0)
        tot = sum(ns)
        tl, tp, _ = eng.close(_params(), [0, 1, 2],
                              [n / tot for n in ns], round_id=0)
        _bitwise(pull.lora, tl)
        assert pull.w0_digest == w0_digest(eng.specs, tp)


HET_RANKS = (1, 2, 1)


@pytest.fixture
def hetero_served():
    """A booted ragged-rank server: 3 clients at ranks (1, 2, 1) against the
    rank-2 template, 2 rounds, obs trace for assertable counters."""
    fed_cfg = FedConfig(num_clients=3, rounds=2, obs="trace",
                        method="hetero", client_ranks=HET_RANKS)
    srv = FederationServer(_params(), _template(), scale=0.5,
                           fed_cfg=fed_cfg, serve_cfg=ServeConfig(port=0))
    httpd = start_http_server(srv, port=0)
    url = f"http://127.0.0.1:{httpd.server_address[1]}"
    yield srv, url
    httpd.shutdown()


class TestHeteroServe:
    """Serve e2e for ragged-rank rounds: mixed-rank uplinks at each client's
    TRUE width cross the socket, the server pads at decode, and the HTTP
    close is bitwise identical to an in-process twin that pads with
    ``pad_adapters`` and closes through ``close_hetero`` — so the wire path
    and the trainer path are the same computation. The wrong-rank POST must
    bounce 422 with ``uplink.quarantined[rank]`` and leave the lane open."""

    def _twin(self, rounds, delivered_per_round):
        eng = RoundCloseEngine(_params(), _template(), c_max=3, scale=0.5,
                               backend="auto", method="hetero",
                               client_ranks=list(HET_RANKS))
        cps = [_params()] * 3
        tl = None
        for rnd in range(rounds):
            eng.buffers.begin_round({i: i for i in range(3)}, round_id=rnd)
            delivered = delivered_per_round[rnd]
            for i in delivered:
                eng.buffers.write(
                    i, pad_adapters(_ragged_delta(rnd, i, HET_RANKS[i]), R),
                    round_id=rnd, rank=HET_RANKS[i])
            new_cp, _loras, tl, div = eng.close_hetero(
                cps, list(delivered), round_id=rnd)
            for i, p in new_cp.items():
                cps[i] = p
            div.resolve()
        return tl, cps, eng

    def test_mixed_rank_rounds_close_bitwise_vs_inprocess_twin(
            self, hetero_served):
        srv, url = hetero_served
        clients = [FedClient(url, i) for i in range(3)]
        for rnd in range(2):
            for i, c in enumerate(clients):
                resp = c.submit_delta(_ragged_delta(rnd, i, HET_RANKS[i]),
                                      round_id=rnd, rank=HET_RANKS[i])
                assert resp["status"] == "accepted"
        pull = clients[0].pull_latest()
        assert pull.version == 2
        tl, cps, eng = self._twin(2, [(0, 1, 2), (0, 1, 2)])
        _bitwise(pull.lora, tl)
        # the ragged witness: one digest chained over EVERY client's folded
        # base (each absorbed a different rank-r_i residual)
        assert pull.w0_digest == hetero_w0_digest(eng.specs, cps)
        # per-client adapters come back at each client's own rank
        for i in range(3):
            assert srv.client_loras[i]["blk"]["q"]["a"].shape == \
                (M, HET_RANKS[i])

    def test_wrong_rank_422_quarantined_lane_stays_open(self, hetero_served):
        srv, url = hetero_served
        c0 = FedClient(url, 0)
        # declared rank beyond the registered r_max → rank quarantine
        with pytest.raises(TransportError) as ei:
            c0.submit_delta(_delta(0, 0), round_id=0, rank=R + 3)
        assert ei.value.reason == "rank"
        assert not isinstance(ei.value, StaleUplinkError)
        # declared rank legal but the tensors' rank axis matches neither the
        # declaration nor r_max → also a rank quarantine, not plain shape
        with pytest.raises(TransportError) as ei:
            c0.submit_delta(_ragged_delta(0, 0, R + 1), round_id=0, rank=1)
        assert ei.value.reason == "rank"
        snap = srv.rec.metrics.snapshot()["counters"]
        assert snap["uplink.quarantined[rank]"] == 2
        # neither quarantine consumed the lane: the real delta still lands
        resp = c0.submit_delta(_ragged_delta(0, 0, HET_RANKS[0]),
                               round_id=0, rank=HET_RANKS[0])
        assert resp["status"] == "accepted"
        tot = srv.ledger.round_totals(0)
        assert tot.get("quarantined_bytes", 0) > 0

    def test_quorum_deadline_hetero_close_exact_over_subset(self):
        fed_cfg = FedConfig(num_clients=3, rounds=1, min_quorum=2,
                            round_deadline=0.4, method="hetero",
                            client_ranks=HET_RANKS)
        srv = FederationServer(_params(), _template(), scale=0.5,
                               fed_cfg=fed_cfg, serve_cfg=ServeConfig(port=0))
        httpd = start_http_server(srv, port=0)
        url = f"http://127.0.0.1:{httpd.server_address[1]}"
        try:
            for i in (0, 2):
                FedClient(url, i).submit_delta(
                    _ragged_delta(0, i, HET_RANKS[i]), round_id=0,
                    rank=HET_RANKS[i])
            assert srv.version == 0
            deadline = time.monotonic() + 5.0
            while srv.version == 0 and time.monotonic() < deadline:
                srv.tick()
                time.sleep(0.02)
            assert srv.version == 1 and srv.done
            pull = FedClient(url, 0).pull_latest()
        finally:
            httpd.shutdown()
        tl, cps, eng = self._twin(1, [(0, 2)])
        _bitwise(pull.lora, tl)
        assert pull.w0_digest == hetero_w0_digest(eng.specs, cps)

    def test_uniform_payload_rank_header_absent(self):
        # legacy frames carry no rank key; a rank-tagged frame round-trips
        c = AdapterCodec("none")
        plain = c.encode(_delta(0, 0), round_id=0, client_id=0)
        assert b'"rank"' not in payload_to_wire(plain)
        assert payload_from_wire(payload_to_wire(plain)).rank is None
        tagged = c.encode(_ragged_delta(0, 0, 1), round_id=0, client_id=0,
                          rank=1)
        assert payload_from_wire(payload_to_wire(tagged)).rank == 1


class TestHTTPStatusMapping:
    def test_auth_401(self, served):
        srv, url = served
        with pytest.raises(TransportError) as ei:
            FedClient(url, 0, token="wrong").submit_delta(_delta(0, 0),
                                                          round_id=0)
        assert ei.value.reason == "auth"
        assert srv.rec.metrics.snapshot()["counters"][
            "uplink.http_rejected[auth]"] == 1

    def test_unknown_client_403(self, served):
        srv, url = served
        with pytest.raises(TransportError) as ei:
            FedClient(url, 99, token="tok").submit_delta(_delta(0, 99),
                                                         round_id=0)
        assert ei.value.reason == "unknown_client"

    def test_malformed_body_400(self, served):
        srv, url = served
        req = urllib.request.Request(
            f"{url}/v1/rounds/0/deltas", data=b"not a frame",
            headers={"Authorization": "Bearer tok"}, method="POST")
        try:
            urllib.request.urlopen(req)
            raise AssertionError("expected HTTP 400")
        except urllib.error.HTTPError as e:
            assert e.code == 400

    def test_duplicate_lane_409_replay_after_close_409(self, served):
        srv, url = served
        c0 = FedClient(url, 0, token="tok")
        c0.submit_delta(_delta(0, 0), round_id=0)
        with pytest.raises(StaleUplinkError):       # duplicate lane
            c0.submit_delta(_delta(0, 0), round_id=0)
        for i in (1, 2):
            FedClient(url, i, token="tok").submit_delta(_delta(0, i),
                                                        round_id=0)
        with pytest.raises(StaleUplinkError):       # replay: round 0 closed
            FedClient(url, 1, token="tok").submit_delta(_delta(0, 1),
                                                        round_id=0)

    def test_quarantine_422_reason_counted(self, served):
        srv, url = served
        bad = _delta(0, 0)
        bad["blk"]["q"]["a"][0, 0] = np.nan
        with pytest.raises(TransportError) as ei:
            FedClient(url, 0, token="tok").submit_delta(bad, round_id=0)
        assert ei.value.reason == "nonfinite"
        snap = srv.rec.metrics.snapshot()["counters"]
        assert snap["uplink.quarantined[nonfinite]"] == 1
        # the quarantined bytes are ledgered under their own direction
        tot = srv.ledger.round_totals(0)
        assert tot.get("quarantined_bytes", 0) > 0
        assert tot["uplink_bytes"] == 0

    def test_quota_429_then_retry_exhaustion(self, served):
        srv, url = served
        c = FedClient(url, 0, token="tok", retries=1, backoff=0.01)
        c.submit_delta(_delta(0, 0), round_id=0)
        with pytest.raises(StaleUplinkError):
            c.submit_delta(_delta(0, 0), round_id=0)  # dup → quota 2/2 spent
        with pytest.raises(TransportError) as ei:
            c.submit_delta(_delta(0, 0), round_id=0)  # 429 until budget dies
        assert ei.value.reason == "retries_exhausted"
        snap = srv.rec.metrics.snapshot()["counters"]
        assert snap["uplink.http_rejected[quota]"] == 2  # initial + 1 retry


class TestDeadlineQuorum:
    def test_wall_deadline_closes_at_quorum_without_posts(self):
        fed_cfg = FedConfig(num_clients=3, rounds=1, min_quorum=2,
                            round_deadline=0.4)
        srv = FederationServer(_params(), _template(), scale=0.5,
                               fed_cfg=fed_cfg, serve_cfg=ServeConfig(port=0))
        httpd = start_http_server(srv, port=0)
        url = f"http://127.0.0.1:{httpd.server_address[1]}"
        try:
            for i in (0, 2):
                FedClient(url, i).submit_delta(_delta(0, i), round_id=0)
            assert srv.version == 0  # quorum met but deadline not expired
            deadline = time.monotonic() + 5.0
            while srv.version == 0 and time.monotonic() < deadline:
                srv.tick()          # wall deadline expires → quorum close
                time.sleep(0.02)
            assert srv.version == 1 and srv.done
            pull = FedClient(url, 0).pull_latest()
        finally:
            httpd.shutdown()
        # exact over the DELIVERED subset only
        eng = RoundCloseEngine(_params(), _template(), c_max=3, scale=0.5,
                               backend="auto")
        eng.buffers.begin_round({i: i for i in range(3)}, round_id=0)
        for i in (0, 2):
            eng.buffers.write(i, _delta(0, i), round_id=0)
        tl, tp, _ = eng.close(_params(), [0, 2], round_id=0)
        _bitwise(pull.lora, tl)
        assert pull.w0_digest == w0_digest(eng.specs, tp)


class TestHTTPFramingReconciliation:
    def test_http_bytes_equal_payload_plus_overhead(self, served):
        """Satellite fix regression: every on-the-wire octet is either
        payload (uplink/quarantined/dropped ledger directions) or overhead
        (http_overhead direction == uplink.http_overhead_bytes counter) —
        nothing silently folded into payload byte counts."""
        srv, url = served
        bad = _delta(0, 1)
        bad["blk"]["q"]["b"][0, 0] = np.inf
        c0, c1 = (FedClient(url, i, token="tok") for i in (0, 1))
        c0.submit_delta(_delta(0, 0), round_id=0)
        with pytest.raises(StaleUplinkError):
            c0.submit_delta(_delta(0, 0), round_id=0)   # dropped (duplicate)
        with pytest.raises(TransportError):
            c1.submit_delta(bad, round_id=0)            # quarantined
        snap = srv.rec.metrics.snapshot()["counters"]
        tot = srv.ledger.round_totals(0)
        payload_bytes = (tot["uplink_bytes"] + tot.get("quarantined_bytes", 0)
                        + tot.get("dropped_bytes", 0))
        assert tot["uplink_params"] > 0
        assert tot["http_overhead_params"] == 0      # raw octets, no params
        assert snap["uplink.http_overhead_bytes"] == tot["http_overhead_bytes"]
        assert snap["uplink.http_bytes"] == \
            payload_bytes + snap["uplink.http_overhead_bytes"]

    def test_downlink_frame_overhead_tracked(self, served):
        srv, url = served
        FedClient(url, 0, token="tok").pull_latest()
        tot = srv.ledger.round_totals(0)  # version 0 downlink
        assert tot["downlink_bytes"] > 0
        assert tot["http_overhead_bytes"] > 0
        snap = srv.rec.metrics.snapshot()["counters"]
        assert snap["downlink.http_bytes"] == \
            tot["downlink_bytes"] + tot["http_overhead_bytes"]


class TestSimClockWallMode:
    def test_sim_mode_unchanged_bitwise(self):
        c = SimClock()
        c.advance(0.1)
        c.advance_to(1.5)
        assert c.now() == 1.5
        c2 = SimClock()
        c2.load_state(c.state_dict())
        assert c2.now() == 1.5

    def test_wall_mode_tracks_elapsed_time(self):
        fake = [100.0]
        c = SimClock(now_fn=lambda: fake[0])
        assert c.now() == 0.0
        fake[0] = 100.5
        assert c.now() == pytest.approx(0.5)

    def test_wall_mode_advance_is_a_floor(self):
        fake = [0.0]
        c = SimClock(now_fn=lambda: fake[0])
        c.advance(2.0)                      # floor: at-least-2s later
        assert c.now() == 2.0
        fake[0] = 1.0                       # wall behind the floor
        assert c.now() == 2.0               # monotone
        fake[0] = 3.5
        assert c.now() == pytest.approx(3.5)

    def test_wall_mode_state_round_trip(self):
        fake = [10.0]
        c = SimClock(now_fn=lambda: fake[0])
        fake[0] = 11.0
        state = c.state_dict()
        assert state["t"] == pytest.approx(1.0)
        c2 = SimClock(now_fn=lambda: fake[0])
        c2.load_state(state)
        assert c2.now() == pytest.approx(1.0)   # restored value is origin
        fake[0] = 12.5
        assert c2.now() == pytest.approx(2.5)
