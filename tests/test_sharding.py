"""Sharding-spec rules: divisibility guards, 2D layouts, cache/batch specs.

These tests run on the single CPU device using abstract mesh-shape math only
(no distributed execution needed to validate the RULES); the subprocess test
in test_dryrun_smoke.py exercises a real multi-device jit.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, LoRAConfig, get_config
from repro.launch.steps import abstract_state, input_specs
from repro.models import build_model
from repro.sharding import batch_spec, cache_spec, param_spec, tree_specs
from repro.util.tree import flatten_with_paths


class FakeMesh:
    """Just enough of a Mesh for the spec rules: named axis sizes."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)
        self.size = 1
        for v in axes.values():
            self.size *= v


MESH = FakeMesh(data=16, model=16)


def _abstract_params(name):
    cfg = get_config(name)
    model = build_model(cfg)
    params, lora, _ = abstract_state(model, cfg, LoRAConfig(rank=8))
    return cfg, params, lora


@pytest.mark.parametrize("name", list(ASSIGNED))
def test_param_specs_divisible(name):
    """Every sharded axis must divide evenly — the guard's contract."""
    cfg, params, lora = _abstract_params(name)
    for tree in (params, lora):
        for path, leaf in flatten_with_paths(tree).items():
            spec = param_spec(path, leaf, MESH)
            assert len(spec) <= leaf.ndim, path
            for dim, axis in zip(leaf.shape, spec):
                if axis is None:
                    continue
                size = MESH.shape[axis] if isinstance(axis, str) else 16
                assert dim % size == 0, f"{path}: {dim} % {size} != 0"


def test_column_row_pairing():
    cfg, params, _ = _abstract_params("granite-8b")
    flat = flatten_with_paths(params)
    qk = [p for p in flat if p.endswith("q_proj/kernel")][0]
    ok = [p for p in flat if p.endswith("o_proj/kernel")][0]
    q_spec = param_spec(qk, flat[qk], MESH)
    o_spec = param_spec(ok, flat[ok], MESH)
    assert q_spec[-1] == "model" and q_spec[-2] == "data"  # column + FSDP
    assert o_spec[-2] == "model" and o_spec[-1] == "data"  # row + FSDP


def test_lora_factors_replicated():
    cfg, params, lora = _abstract_params("qwen2.5-3b")
    for path, leaf in flatten_with_paths(lora).items():
        spec = param_spec(path, leaf, MESH)
        assert all(s is None for s in spec), f"lora factor sharded: {path}"


def test_expert_parallel_spec():
    cfg, params, _ = _abstract_params("mixtral-8x22b")
    flat = flatten_with_paths(params)
    path = [p for p in flat if "experts/up_proj" in p][0]
    spec = param_spec(path, flat[path], MESH)
    # (L, E, d, ff) → expert axis on model — but E=8 < 16 → guard nullifies;
    # the guard must kick in for mixtral (8 experts) and hold for deepseek.
    assert spec[1] is None  # 8 % 16 != 0 → replicated experts for mixtral

    cfg2, params2, _ = _abstract_params("deepseek-v2-236b")
    flat2 = flatten_with_paths(params2)
    path2 = [p for p in flat2 if "experts/up_proj" in p][0]
    spec2 = param_spec(path2, flat2[path2], MESH)
    assert spec2[1] == "model"  # 160 % 16 == 0 → expert-parallel


def test_vocab_guard_whisper():
    """51865 is not divisible by 16 → embedding falls back to replication."""
    cfg, params, _ = _abstract_params("whisper-medium")
    flat = flatten_with_paths(params)
    path = [p for p in flat if p == "embed/embedding"][0]
    spec = param_spec(path, flat[path], MESH)
    assert spec[0] is None


def test_cache_specs():
    cfg = get_config("granite-8b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768))
    flat = flatten_with_paths(cache)
    kpath = [p for p in flat if p.endswith("/k")][0]
    spec = cache_spec(kpath, flat[kpath], MESH, "data")
    # (L, B, S, KV, D): batch on data, SEQ on model
    assert spec == P(None, "data", "model", None, None)


def test_batch_spec_multipod():
    mesh = FakeMesh(pod=2, data=16, model=16)
    cfg = get_config("qwen2.5-3b")
    from repro.configs import get_shape
    batch = input_specs(cfg, get_shape("train_4k"))
    spec = batch_spec("tokens", batch["tokens"], mesh, ("pod", "data"))
    assert spec == P(("pod", "data"), None)
