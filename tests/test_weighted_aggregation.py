"""Weighted exact aggregation (fedsrv regime): the residual identity
Σwᵢ aᵢbᵢ = ā b̄ + ΔW_res must hold exactly for non-uniform weights, subset
participation, and stacked-layer leaves — and uniform weights must reproduce
the unweighted operators bit-for-bit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    apply_residual,
    assign_after_aggregation,
    fedex_aggregate,
    fedex_residual,
    fedit_aggregate,
    normalize_weights,
    per_client_residuals,
    product_mean,
    residual_factors,
    tree_mean,
)


def make_client_loras(k=4, m=24, r=4, n=16, seed=0, layers=None):
    rng = np.random.default_rng(seed)
    lead = () if layers is None else (layers,)
    return [{
        "blk": {
            "q_proj": {
                "a": jnp.asarray(rng.normal(size=lead + (m, r)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=lead + (r, n)), jnp.float32),
            },
        }
    } for _ in range(k)]


def dense_update(lora):
    return jnp.matmul(lora["blk"]["q_proj"]["a"], lora["blk"]["q_proj"]["b"])


def random_weights(k, seed):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.1, 10.0, size=k)
    return (w / w.sum()).tolist()


class TestNormalizeWeights:
    def test_none_and_uniform_fold_to_none(self):
        assert normalize_weights(None, 3) is None
        assert normalize_weights([1, 1, 1], 3) is None
        assert normalize_weights([5.0, 5.0], 2) is None

    def test_normalizes_to_unit_sum(self):
        w = normalize_weights([1, 3], 2)
        assert w == [0.25, 0.75]

    def test_rejects_bad_weights(self):
        with pytest.raises(ValueError):
            normalize_weights([1, 2], 3)
        with pytest.raises(ValueError):
            normalize_weights([-1, 2], 2)
        with pytest.raises(ValueError):
            normalize_weights([0.0, 0.0], 2)


class TestUniformRegression:
    """Uniform weights must reproduce the unweighted operators EXACTLY
    (same sum/k code path, bitwise)."""

    def test_fedex_aggregate_bitwise(self):
        loras = make_client_loras()
        k = len(loras)
        g0, res0 = fedex_aggregate(loras)
        g1, res1 = fedex_aggregate(loras, [1.0 / k] * k)
        for x, y in zip(jax.tree.leaves((g0, res0)), jax.tree.leaves((g1, res1))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_tree_mean_and_product_mean_bitwise(self):
        loras = make_client_loras(k=3)
        for op in (tree_mean, product_mean, fedit_aggregate):
            u = op(loras)
            w = op(loras, [2.0, 2.0, 2.0])  # equal but non-unit → still uniform
            for x, y in zip(jax.tree.leaves(u), jax.tree.leaves(w)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestWeightedExactness:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_weighted_identity(self, seed):
        """apply_residual(W0, weighted_fedex) == W0 + scale·Σwᵢaᵢbᵢ."""
        loras = make_client_loras(seed=seed)
        w = random_weights(len(loras), seed + 10)
        g, res = fedex_aggregate(loras, w)
        ideal = sum(wi * dense_update(l) for wi, l in zip(w, loras))

        scale = 1.7
        params = {"blk": {"q_proj": {"kernel": jnp.asarray(
            np.random.default_rng(seed).normal(size=(24, 16)), jnp.float32)}}}
        w_fedex = (apply_residual(params, res, scale)["blk"]["q_proj"]["kernel"]
                   + scale * dense_update(g))
        w_ideal = params["blk"]["q_proj"]["kernel"] + scale * ideal
        np.testing.assert_allclose(w_fedex, w_ideal, rtol=1e-5, atol=1e-5)

    def test_subset_participation(self):
        """Weights over a sampled subset: identity holds on the subset."""
        loras = make_client_loras(k=6, seed=3)
        subset = [loras[i] for i in (0, 2, 5)]
        n = [120, 40, 440]  # example counts → w = n/Σn
        w = [x / sum(n) for x in n]
        g, res = fedex_aggregate(subset, n)  # unnormalized counts accepted
        ideal = sum(wi * dense_update(l) for wi, l in zip(w, subset))
        got = dense_update(g) + res["blk"]["q_proj"]
        np.testing.assert_allclose(got, ideal, rtol=1e-5, atol=1e-6)

    def test_stacked_layer_layout(self):
        loras = make_client_loras(k=3, layers=5, seed=4)
        w = random_weights(3, 7)
        g, res = fedex_aggregate(loras, w)
        ideal = sum(wi * dense_update(l) for wi, l in zip(w, loras))
        got = dense_update(g) + res["blk"]["q_proj"]
        assert got.shape == (5, 24, 16)
        np.testing.assert_allclose(got, ideal, rtol=1e-5, atol=1e-6)

    def test_weighted_residual_nonzero_vs_uniform(self):
        loras = make_client_loras(seed=5)
        _, res_u = fedex_aggregate(loras)
        _, res_w = fedex_aggregate(loras, [0.7, 0.1, 0.1, 0.1])
        assert float(jnp.abs(res_u["blk"]["q_proj"]
                             - res_w["blk"]["q_proj"]).max()) > 1e-4

    def test_per_client_residuals_weighted(self):
        loras = make_client_loras(k=3, seed=6)
        w = random_weights(3, 8)
        residuals = per_client_residuals(loras, w)
        ideal = sum(wi * dense_update(l) for wi, l in zip(w, loras))
        for lora_i, res_i in zip(loras, residuals):
            got = dense_update(lora_i) + res_i["blk"]["q_proj"]
            np.testing.assert_allclose(got, ideal, rtol=1e-5, atol=1e-5)

    def test_weighted_factored_form_lossless(self):
        """decompose.residual_factors stays exact under non-uniform weights."""
        loras = make_client_loras(k=4, m=32, n=20, seed=7)
        w = random_weights(4, 9)
        _, res = fedex_aggregate(loras, w)
        factors = [l["blk"]["q_proj"] for l in loras]
        L, R = residual_factors(factors, w)
        assert L.shape[1] == (len(loras) + 1) * 4
        np.testing.assert_allclose(np.asarray(L @ R),
                                   np.asarray(res["blk"]["q_proj"]),
                                   rtol=1e-5, atol=1e-5)

    def test_fedex_residual_explicit_global(self):
        loras = make_client_loras(seed=8)
        w = random_weights(len(loras), 11)
        g = fedit_aggregate(loras, w)
        res = fedex_residual(loras, g, w)
        _, res2 = fedex_aggregate(loras, w)
        np.testing.assert_allclose(np.asarray(res["blk"]["q_proj"]),
                                   np.asarray(res2["blk"]["q_proj"]),
                                   rtol=1e-6)


class TestReinitSeeding:
    def test_reinit_deterministic_and_shape_independent(self):
        """The fold-in key is a stable per-leaf counter — identical across
        calls (and processes; no PYTHONHASHSEED dependence), and two leaves
        with the SAME shape get DIFFERENT draws."""
        loras = [{
            "blk": {
                "q_proj": {"a": jnp.ones((8, 2)), "b": jnp.zeros((2, 8))},
                "k_proj": {"a": jnp.ones((8, 2)), "b": jnp.zeros((2, 8))},
            }
        } for _ in range(2)]
        new1, _ = assign_after_aggregation("reinit", loras, jax.random.key(3))
        new2, _ = assign_after_aggregation("reinit", loras, jax.random.key(3))
        a1q = np.asarray(new1[0]["blk"]["q_proj"]["a"])
        a2q = np.asarray(new2[0]["blk"]["q_proj"]["a"])
        np.testing.assert_array_equal(a1q, a2q)
        # same-shape leaves must not share an init (old hash(str(shape)) bug)
        a1k = np.asarray(new1[0]["blk"]["k_proj"]["a"])
        assert np.abs(a1q - a1k).max() > 0

    def test_reinit_weighted_exactness(self):
        loras = make_client_loras(seed=9)
        w = random_weights(len(loras), 12)
        new_loras, residual = assign_after_aggregation(
            "reinit", loras, jax.random.key(0), w)
        ideal = sum(wi * dense_update(l) for wi, l in zip(w, loras))
        got = dense_update(new_loras[0]) + residual["blk"]["q_proj"]
        np.testing.assert_allclose(got, ideal, rtol=1e-5, atol=1e-5)
